//! Platform description + calibration (the simulated i.MX95).
//!
//! Calibration strategy (DESIGN.md §5): per-(model, core-count) CPU
//! efficiency tables + a GPU throughput/overhead pair, anchored so that the
//! derived cost coefficients at S_L = 63 reproduce the paper's Fig. 6 /
//! Table II operating points (c_hetero(1) ≈ 0.358 → S = 1.68,
//! c_homo(1) ≈ 0.80, hetero infeasible for ≥ 3 cores, ...). Tables are
//! deliberately *tables* — measured-on-silicon numbers are not smooth, and
//! the paper's own values are non-monotonic in core count.
//!
//! The memory model uses *paper-scale* parameter counts (Llama 3.2 3B/1B)
//! so the paper's memory-infeasibility footnotes reproduce: FP16 target
//! does not fit, which forces the semi-quantized deployment.

use crate::models::{ModelSpec, Role, Scheme};
use crate::util::json::Json;

/// CPU cluster calibration.
#[derive(Debug, Clone)]
pub struct CpuSpec {
    pub name: String,
    pub cores: usize,
    /// Peak GFLOP/s of a single core.
    pub peak_gflops_per_core: f64,
    /// Effective utilization for the *target*-sized model, per core count
    /// (index 0 = 1 core).
    pub eff_target: Vec<f64>,
    /// Same for the *drafter*-sized model (smaller GEMMs utilize worse).
    pub eff_drafter: Vec<f64>,
    /// Per-inference-call dispatch overhead (runtime API boundary), seconds.
    pub dispatch_overhead_s: f64,
    /// Throughput multiplier for int8 linears (A55 dot-product extensions).
    pub int8_speedup: f64,
}

/// GPU calibration.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: String,
    pub shaders: usize,
    /// Effective GFLOP/s for fp models.
    pub peak_gflops: f64,
    /// Per-call dispatch overhead, seconds (queue submit + sync).
    pub dispatch_overhead_s: f64,
    /// INT8 is promoted to FP32 on Mali (paper footnote 3): quantized
    /// linears pay this penalty instead of gaining.
    pub int8_promotion_penalty: f64,
    /// Whether native int8 is supported at all (false on this Mali).
    pub supports_int8: bool,
}

/// Memory model at paper scale.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    /// Paper-scale parameter counts per role (Llama 3.2: 3B / 1B).
    pub scaled_params_target: f64,
    pub scaled_params_drafter: f64,
    /// Bytes/param: fp16 = 2, w8a8 = 1.
    pub bytes_fp: f64,
    pub bytes_w8a8: f64,
    /// Device memory budget for model weights + runtime, bytes.
    pub budget_bytes: f64,
    /// Fixed size of one KV-cache page, bytes ([`crate::kvcache`]).
    pub kv_page_bytes: f64,
    /// KV page-pool capacity carved out of the DRAM partition each PU's
    /// runtime arena owns (pages, per worker).
    pub kv_pages_cpu: usize,
    pub kv_pages_gpu: usize,
    /// Effective DRAM bandwidth for streaming cached KV back through the
    /// attention kernels, GB/s (the memory-traffic latency term).
    pub dram_gbps: f64,
}

impl MemoryModel {
    /// Bytes/element under a quantization scheme.
    pub fn scheme_bytes(&self, scheme: Scheme) -> f64 {
        match scheme {
            Scheme::Fp => self.bytes_fp,
            Scheme::W8a8 => self.bytes_w8a8,
        }
    }

    /// KV page-pool capacity of a physical PU (pages, per worker).
    pub fn kv_pages(&self, pu: super::pu::PuId) -> usize {
        match pu {
            super::pu::PuId::Cpu => self.kv_pages_cpu,
            super::pu::PuId::Gpu => self.kv_pages_gpu,
        }
    }
    pub fn role_bytes(&self, role: Role, scheme: Scheme) -> f64 {
        let params = match role {
            Role::Target => self.scaled_params_target,
            Role::Drafter => self.scaled_params_drafter,
        };
        params * self.scheme_bytes(scheme)
    }

    /// Can a (target scheme, drafter scheme) pair be resident together?
    /// Reproduces the paper's exclusions: FP/FP and quantized-drafter-only
    /// configurations exceed the budget (§IV-A footnote 2).
    pub fn pair_fits(&self, target: Scheme, drafter: Scheme) -> bool {
        self.role_bytes(Role::Target, target) + self.role_bytes(Role::Drafter, drafter)
            <= self.budget_bytes
    }
}

/// The full simulated platform.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: String,
    pub cpu: CpuSpec,
    pub gpu: GpuSpec,
    pub memory: MemoryModel,
}

impl Default for Platform {
    /// The built-in i.MX95 calibration (clippy `new_without_default`-style
    /// tidy: the platform with a canonical zero-argument constructor now
    /// also implements `Default`).
    fn default() -> Platform {
        Platform::imx95()
    }
}

impl Platform {
    /// Built-in i.MX95 calibration (see module docs and DESIGN.md §5).
    pub fn imx95() -> Platform {
        Platform {
            name: "imx95-sim".to_string(),
            cpu: CpuSpec {
                name: "Cortex-A55".to_string(),
                cores: 6,
                peak_gflops_per_core: 5.0,
                eff_target: vec![0.850, 0.873, 0.840, 0.800, 0.740, 0.700],
                eff_drafter: vec![0.3996, 0.4007, 0.3397, 0.3167, 0.3231, 0.2713],
                dispatch_overhead_s: 80e-6,
                int8_speedup: 1.35,
            },
            gpu: GpuSpec {
                name: "Mali-G310".to_string(),
                shaders: 1,
                peak_gflops: 4.6731,
                dispatch_overhead_s: 350e-6,
                int8_promotion_penalty: 1.8,
                supports_int8: false,
            },
            memory: MemoryModel {
                scaled_params_target: 3.0e9,
                scaled_params_drafter: 1.0e9,
                bytes_fp: 2.0,   // fp16 at paper scale
                bytes_w8a8: 1.0, // int8 weights
                budget_bytes: 5.5e9,
                kv_page_bytes: 16.0 * 1024.0,
                kv_pages_cpu: 2048,
                kv_pages_gpu: 512,
                dram_gbps: 12.8, // LPDDR5 partition effectively available
            },
        }
    }

    /// Built-in datacenter-class verifier stand-in for the fleet's cloud
    /// tier ([`crate::fleet`]): a server accelerator orders of magnitude
    /// past the Mali, negligible per-call overhead relative to the link,
    /// and enough memory that no pairing is excluded. Deliberately coarse
    /// — the cloud side of collaborative speculation is dominated by the
    /// network model, not by single-percent compute calibration.
    pub fn cloud() -> Platform {
        Platform {
            name: "cloud-sim".to_string(),
            cpu: CpuSpec {
                name: "server-x86".to_string(),
                cores: 16,
                peak_gflops_per_core: 80.0,
                eff_target: vec![0.85; 16],
                eff_drafter: vec![0.70; 16],
                dispatch_overhead_s: 20e-6,
                int8_speedup: 2.0,
            },
            gpu: GpuSpec {
                name: "server-accelerator".to_string(),
                shaders: 1,
                peak_gflops: 2000.0,
                dispatch_overhead_s: 30e-6,
                int8_promotion_penalty: 1.0,
                supports_int8: true,
            },
            memory: MemoryModel {
                scaled_params_target: 3.0e9,
                scaled_params_drafter: 1.0e9,
                bytes_fp: 2.0,
                bytes_w8a8: 1.0,
                budget_bytes: 80.0e9,
                kv_page_bytes: 16.0 * 1024.0,
                kv_pages_cpu: 65536,
                kv_pages_gpu: 65536,
                dram_gbps: 900.0,
            },
        }
    }

    /// Resolve a built-in calibration by name (fleet files name device
    /// platforms as `"imx95"` / `"cloud"` instead of repeating JSON).
    pub fn builtin(name: &str) -> Option<Platform> {
        match name {
            "imx95" | "imx95-sim" => Some(Platform::imx95()),
            "cloud" | "cloud-sim" => Some(Platform::cloud()),
            _ => None,
        }
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Platform> {
        let mut p = Platform::imx95();
        if let Some(v) = j.get("name").and_then(Json::as_str) {
            p.name = v.to_string();
        }
        if let Some(cpu) = j.get("cpu") {
            let c = &mut p.cpu;
            if let Some(v) = cpu.get("name").and_then(Json::as_str) {
                c.name = v.into();
            }
            if let Some(v) = cpu.get("cores").and_then(Json::as_usize) {
                c.cores = v;
            }
            if let Some(v) = cpu.get("peak_gflops_per_core").and_then(Json::as_f64) {
                c.peak_gflops_per_core = v;
            }
            if let Some(v) = cpu.get("eff_target").and_then(Json::as_arr) {
                c.eff_target = v.iter().filter_map(Json::as_f64).collect();
            }
            if let Some(v) = cpu.get("eff_drafter").and_then(Json::as_arr) {
                c.eff_drafter = v.iter().filter_map(Json::as_f64).collect();
            }
            if let Some(v) = cpu.get("dispatch_overhead_us").and_then(Json::as_f64) {
                c.dispatch_overhead_s = v * 1e-6;
            }
            if let Some(v) = cpu.get("int8_speedup").and_then(Json::as_f64) {
                c.int8_speedup = v;
            }
        }
        if let Some(gpu) = j.get("gpu") {
            let g = &mut p.gpu;
            if let Some(v) = gpu.get("name").and_then(Json::as_str) {
                g.name = v.into();
            }
            if let Some(v) = gpu.get("shaders").and_then(Json::as_usize) {
                g.shaders = v;
            }
            if let Some(v) = gpu.get("peak_gflops").and_then(Json::as_f64) {
                g.peak_gflops = v;
            }
            if let Some(v) = gpu.get("dispatch_overhead_us").and_then(Json::as_f64) {
                g.dispatch_overhead_s = v * 1e-6;
            }
            if let Some(v) = gpu.get("int8_promotion_penalty").and_then(Json::as_f64) {
                g.int8_promotion_penalty = v;
            }
            if let Some(v) = gpu.get("supports_int8").and_then(Json::as_bool) {
                g.supports_int8 = v;
            }
        }
        if let Some(mem) = j.get("memory") {
            let m = &mut p.memory;
            if let Some(v) = mem.get("scaled_params_target").and_then(Json::as_f64) {
                m.scaled_params_target = v;
            }
            if let Some(v) = mem.get("scaled_params_drafter").and_then(Json::as_f64) {
                m.scaled_params_drafter = v;
            }
            if let Some(v) = mem.get("budget_gb").and_then(Json::as_f64) {
                m.budget_bytes = v * 1e9;
            }
            if let Some(v) = mem.get("kv_page_bytes").and_then(Json::as_f64) {
                m.kv_page_bytes = v;
            }
            if let Some(v) = mem.get("kv_pages_cpu").and_then(Json::as_usize) {
                m.kv_pages_cpu = v;
            }
            if let Some(v) = mem.get("kv_pages_gpu").and_then(Json::as_usize) {
                m.kv_pages_gpu = v;
            }
            if let Some(v) = mem.get("dram_gbps").and_then(Json::as_f64) {
                m.dram_gbps = v;
            }
        }
        p.validate()?;
        Ok(p)
    }

    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Platform> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        Platform::from_json(&j)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.cpu.cores >= 1 && self.cpu.cores <= 64);
        anyhow::ensure!(
            self.cpu.eff_target.len() >= self.cpu.cores
                && self.cpu.eff_drafter.len() >= self.cpu.cores,
            "efficiency tables must cover all {} cores",
            self.cpu.cores
        );
        anyhow::ensure!(
            self.cpu.eff_target.iter().chain(&self.cpu.eff_drafter).all(|&e| e > 0.0 && e <= 1.0),
            "efficiencies must be in (0, 1]"
        );
        anyhow::ensure!(self.gpu.peak_gflops > 0.0 && self.cpu.peak_gflops_per_core > 0.0);
        anyhow::ensure!(
            self.gpu.shaders >= 1,
            "gpu.shaders must be >= 1 (it scales the design-variant count)"
        );
        anyhow::ensure!(
            self.memory.kv_page_bytes >= 1024.0,
            "memory.kv_page_bytes must be >= 1024 (one page must hold >= 1 token of KV)"
        );
        anyhow::ensure!(
            self.memory.dram_gbps > 0.0,
            "memory.dram_gbps must be positive"
        );
        Ok(())
    }

    /// Design variants: v = Π nᵢ = cores × shaders (paper §III-B example:
    /// 6 × 1 = 6). Variant k (1-based) = k CPU cores available.
    pub fn design_variants(&self) -> usize {
        self.cpu.cores * self.gpu.shaders
    }

    /// Efficiency lookup for a model role at a core count.
    pub fn cpu_eff(&self, spec: &ModelSpec, cores: usize) -> f64 {
        let table = if spec.name == "drafter" {
            &self.cpu.eff_drafter
        } else {
            &self.cpu.eff_target
        };
        table[(cores - 1).min(table.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_valid() {
        Platform::imx95().validate().unwrap();
        assert_eq!(Platform::imx95().design_variants(), 6);
    }

    #[test]
    fn memory_reproduces_paper_exclusions() {
        let m = Platform::imx95().memory;
        // Paper §IV-A footnote 2: FP/FP and target-FP+drafter-quant don't fit.
        assert!(!m.pair_fits(Scheme::Fp, Scheme::Fp));
        assert!(!m.pair_fits(Scheme::Fp, Scheme::W8a8));
        // Deployed configs fit: semi (target quant) and full quant.
        assert!(m.pair_fits(Scheme::W8a8, Scheme::Fp));
        assert!(m.pair_fits(Scheme::W8a8, Scheme::W8a8));
    }

    #[test]
    fn cloud_builtin_valid_and_resolvable() {
        let c = Platform::cloud();
        c.validate().unwrap();
        // The cloud verifier must actually be fast relative to the edge:
        // a datacenter accelerator, not another Mali.
        assert!(c.gpu.peak_gflops > 100.0 * Platform::imx95().gpu.peak_gflops);
        // Nothing is memory-excluded in the cloud.
        assert!(c.memory.pair_fits(Scheme::Fp, Scheme::Fp));
        assert_eq!(Platform::builtin("imx95").unwrap().name, "imx95-sim");
        assert_eq!(Platform::builtin("cloud").unwrap().name, "cloud-sim");
        assert!(Platform::builtin("tpu-pod").is_none());
    }

    #[test]
    fn json_overrides() {
        let j = Json::parse(
            r#"{"name":"x","cpu":{"peak_gflops_per_core":10.0},
                "gpu":{"peak_gflops":7.0},"memory":{"budget_gb":16.0}}"#,
        )
        .unwrap();
        let p = Platform::from_json(&j).unwrap();
        assert_eq!(p.name, "x");
        assert_eq!(p.cpu.peak_gflops_per_core, 10.0);
        assert_eq!(p.gpu.peak_gflops, 7.0);
        assert!(p.memory.pair_fits(Scheme::Fp, Scheme::Fp)); // 16 GB fits all
    }

    #[test]
    fn kv_memory_fields_default_and_override() {
        let m = Platform::imx95().memory;
        assert_eq!(m.kv_pages(super::super::pu::PuId::Cpu), 2048);
        assert_eq!(m.kv_pages(super::super::pu::PuId::Gpu), 512);
        assert!(m.kv_page_bytes > 0.0 && m.dram_gbps > 0.0);
        let j = Json::parse(
            r#"{"memory":{"kv_page_bytes":8192,"kv_pages_cpu":64,
                "kv_pages_gpu":16,"dram_gbps":25.6}}"#,
        )
        .unwrap();
        let p = Platform::from_json(&j).unwrap();
        assert_eq!(p.memory.kv_page_bytes, 8192.0);
        assert_eq!(p.memory.kv_pages_cpu, 64);
        assert_eq!(p.memory.kv_pages_gpu, 16);
        assert_eq!(p.memory.dram_gbps, 25.6);
        // A page too small to hold a single token's KV is rejected.
        let j = Json::parse(r#"{"memory":{"kv_page_bytes":64}}"#).unwrap();
        assert!(Platform::from_json(&j).is_err());
    }

    #[test]
    fn bad_efficiency_rejected() {
        let j = Json::parse(r#"{"cpu":{"eff_target":[2.0]}}"#).unwrap();
        assert!(Platform::from_json(&j).is_err());
    }

    #[test]
    fn gpu_shaders_override_scales_design_variants() {
        // Regression: `gpu.shaders` used to be silently dropped, so JSON
        // platforms could never change the design-variant count (§III-B:
        // v = cores × shaders).
        let j = Json::parse(r#"{"gpu":{"shaders":2}}"#).unwrap();
        let p = Platform::from_json(&j).unwrap();
        assert_eq!(p.gpu.shaders, 2);
        assert_eq!(p.design_variants(), 12);
    }

    #[test]
    fn zero_gpu_shaders_rejected() {
        let j = Json::parse(r#"{"gpu":{"shaders":0}}"#).unwrap();
        assert!(Platform::from_json(&j).is_err());
    }
}
