//! Deterministic PRNGs (the `rand` crate is unavailable offline).
//!
//! SplitMix64 for seeding, xoshiro256++ for the main stream — the standard
//! pairing. Used by the workload generator (arrival processes), the
//! stochastic accept rule, and the in-tree property-test driver.

/// SplitMix64: tiny, full-period seeder.
#[derive(Debug, Clone)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = SplitMix64(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let out = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        out
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free is overkill here; modulo bias is
        // negligible for n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo + 1) as u64)) as i64
    }

    /// Exponential with rate `lambda` (Poisson-process inter-arrival).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a reference uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn exp_mean_near_inverse_rate() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.02, "{mean}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(13);
        let mut seen = [0usize; 10];
        for _ in 0..10_000 {
            seen[r.below(10)] += 1;
        }
        assert!(seen.iter().all(|&c| c > 700), "{seen:?}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
