//! Summary statistics used by the profiler, metrics and bench harness.

/// Online + batch summary of a sample set (latencies, acceptance rates, ...).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    values: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Summary {
        Summary::default()
    }

    pub fn from_values(values: Vec<f64>) -> Summary {
        Summary { values, sorted: false }
    }

    pub fn push(&mut self, x: f64) {
        self.values.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.values.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (n as f64 - 1.0))
            .sqrt()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.values
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
    }

    /// Linear-interpolated percentile, q in [0, 100].
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let n = self.values.len();
        if n == 1 {
            return self.values[0];
        }
        let rank = (q / 100.0) * (n - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.values[lo] * (1.0 - frac) + self.values[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    /// Five-number box-plot summary (what the paper's Fig. 5 boxes show).
    pub fn box_stats(&mut self) -> BoxStats {
        BoxStats {
            min: self.percentile(0.0),
            q1: self.percentile(25.0),
            median: self.percentile(50.0),
            q3: self.percentile(75.0),
            max: self.percentile(100.0),
            p90: self.percentile(90.0),
            mean: self.mean(),
            n: self.len(),
        }
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }
}

/// Box-plot summary row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoxStats {
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub p90: f64,
    pub mean: f64,
    pub n: usize,
}

impl BoxStats {
    pub fn csv_header() -> &'static str {
        "min,q1,median,q3,max,p90,mean,n"
    }

    pub fn to_csv(&self) -> String {
        format!(
            "{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4},{}",
            self.min, self.q1, self.median, self.q3, self.max, self.p90, self.mean, self.n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let s = Summary::from_values(vec![1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::from_values((1..=100).map(|x| x as f64).collect());
        assert!((s.median() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-12);
        assert!((s.percentile(90.0) - 90.1).abs() < 1e-9);
    }

    #[test]
    fn single_value() {
        let mut s = Summary::from_values(vec![7.0]);
        assert_eq!(s.median(), 7.0);
        assert_eq!(s.percentile(99.0), 7.0);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn empty_is_nan() {
        let mut s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.median().is_nan());
    }

    #[test]
    fn box_stats_ordered() {
        let mut s = Summary::from_values(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        let b = s.box_stats();
        assert!(b.min <= b.q1 && b.q1 <= b.median && b.median <= b.q3 && b.q3 <= b.max);
        assert_eq!(b.n, 5);
    }
}
