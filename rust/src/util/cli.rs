//! Tiny declarative CLI parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.
//! Each binary declares its options up-front so `--help` is generated.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub values: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, Default)]
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Cli {
        Cli { program, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str,
               default: Option<&'static str>) -> Cli {
        self.opts.push(OptSpec { name, help, takes_value: true, default });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Cli {
        self.opts.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let arg = if o.takes_value {
                format!("--{} <value>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {arg:<28} {}{}\n", o.help, def));
        }
        s
    }

    /// Parse an argv slice (excluding the program name).
    pub fn parse(&self, argv: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        for o in &self.opts {
            if let (true, Some(d)) = (o.takes_value, o.default) {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                anyhow::bail!("{}", self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow::anyhow!(
                        "unknown option --{name}\n\n{}", self.usage()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!(
                                    "option --{name} needs a value"))?
                        }
                    };
                    args.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        anyhow::bail!("flag --{name} does not take a value");
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn req(&self, name: &str) -> anyhow::Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))
    }

    pub fn get_usize(&self, name: &str) -> anyhow::Result<Option<usize>> {
        self.get(name)
            .map(|v| v.parse().map_err(|_| anyhow::anyhow!("--{name}: not an integer: {v}")))
            .transpose()
    }

    pub fn get_f64(&self, name: &str) -> anyhow::Result<Option<f64>> {
        self.get(name)
            .map(|v| v.parse().map_err(|_| anyhow::anyhow!("--{name}: not a number: {v}")))
            .transpose()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("alpha", "acceptance rate", Some("0.9"))
            .opt("out", "output dir", None)
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cli().parse(&argv(&["--out", "x"])).unwrap();
        assert_eq!(a.get("alpha"), Some("0.9"));
        assert_eq!(a.get("out"), Some("x"));
        let a = cli().parse(&argv(&["--alpha=0.17"])).unwrap();
        assert_eq!(a.get_f64("alpha").unwrap(), Some(0.17));
    }

    #[test]
    fn flags_and_positional() {
        let a = cli().parse(&argv(&["serve", "--verbose", "extra"])).unwrap();
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["serve", "extra"]);
    }

    #[test]
    fn unknown_option_fails() {
        assert!(cli().parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_fails() {
        assert!(cli().parse(&argv(&["--out"])).is_err());
    }

    #[test]
    fn bad_number_fails() {
        let a = cli().parse(&argv(&["--alpha", "abc"])).unwrap();
        assert!(a.get_f64("alpha").is_err());
    }
}
