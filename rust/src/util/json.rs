//! Minimal JSON codec (serde is unavailable offline — DESIGN.md §1).
//!
//! Full RFC 8259 parsing for everything the repo touches: the artifact
//! manifest, platform calibration files, run configs, result files and the
//! line-JSON server protocol. Serialization is deterministic (object keys
//! keep insertion order).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps deterministic ordering; manifests are machine-written
    /// so key order is not semantically meaningful.
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- constructors ---------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs<I: IntoIterator<Item = (String, Json)>>(it: I) -> Json {
        Json::Obj(it.into_iter().collect())
    }

    // ---- accessors ------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["quant", "act_scales", "target"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| if x >= 0.0 { Some(x as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Insert into an object value (panics on non-objects — builder misuse).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- required-field helpers (errors instead of panics) --------------
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field `{key}`"))
    }

    // ---- parse ------------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- serialize ----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/Inf; null is the conventional stand-in.
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs for astral-plane chars.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            let hi10 = cp - 0xD800;
                            let lo10 = lo.wrapping_sub(0xDC00);
                            char::from_u32(0x10000 + (hi10 << 10) + lo10)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf-8")),
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("bad utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":{"d":null}}"#).unwrap();
        assert_eq!(j.at(&["c", "d"]), Some(&Json::Null));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,true,false,null,"s\"t"],"y":{"z":[]}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
        // Raw multi-byte UTF-8 passes through.
        let j = Json::parse("\"héllo\"").unwrap();
        assert_eq!(j, Json::Str("héllo".into()));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let j = Json::parse(r#"{"a":{"b":[1,2]},"c":"d"}"#).unwrap();
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
    }
}

#[cfg(test)]
mod nan_tests {
    use super::*;

    #[test]
    fn non_finite_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        let mut o = Json::obj();
        o.set("alpha", Json::Num(f64::NAN));
        assert!(Json::parse(&o.to_string()).is_ok());
    }
}
