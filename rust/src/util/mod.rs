//! Substrate utilities.
//!
//! The offline crate registry only carries the `xla` dependency closure, so
//! the usual ecosystem crates (serde, clap, rand, criterion, proptest) are
//! substituted by the small, tested implementations in this module tree —
//! see DESIGN.md §1.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// Monotonic wall-clock helper: seconds since an arbitrary start.
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}
