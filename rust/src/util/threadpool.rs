//! Fixed-size worker pool over std threads + mpsc (tokio is unavailable
//! offline; the coordinator's concurrency needs are classic thread-pool
//! shaped anyway: N engine workers pulling from a shared queue).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple scoped-ish thread pool: submit closures, drop to join.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("specedge-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), handles }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Run `f` over every item, in parallel, collecting results in order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker died");
            out[i] = Some(r);
        }
        out.into_iter().map(|x| x.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect(), |x: usize| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_is_sequentialish() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x: i32| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }
}
