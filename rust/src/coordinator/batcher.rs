//! Dynamic batching for non-speculative (baseline) decode.
//!
//! Without a KV cache, batching is lockstep full-sequence re-encoding:
//! requests grouped into one `forward_batch` call advance one token each
//! per step, padded to a shared bucket. Finished sequences are carried as
//! padding until the whole batch drains (classic static-batching tail —
//! measured and reported, which is exactly why speculative decoding is the
//! more interesting single-stream path on edge).
//!
//! Speculative requests are never batched (the paper is single-stream; the
//! divergent accept lengths would force per-item recompute anyway).

use crate::config::KernelPath;
use crate::models::VariantKey;
use crate::runtime::Engine;
use crate::tokenizer::EOS_ID;

/// Outcome for one batched request.
#[derive(Debug, Clone)]
pub struct BatchItemOutcome {
    pub tokens: Vec<u32>,
    pub target_calls: usize,
    pub real_s: f64,
    /// Simulated seconds attributed to this item (batch cost / batch size —
    /// the standard per-request amortization).
    pub sim_s: f64,
}

/// Lockstep batched greedy decode of up to `prompts.len()` requests.
///
/// `sim_forward(bucket, batch)` supplies the simulated cost of one batched
/// forward (the latency model scales with batch externally).
pub fn batched_baseline(
    engine: &Engine,
    target: VariantKey,
    kernel: KernelPath,
    prompts: &[Vec<u32>],
    max_new: usize,
    sim_forward: &dyn Fn(usize, usize) -> f64,
) -> anyhow::Result<Vec<BatchItemOutcome>> {
    let b = prompts.len();
    anyhow::ensure!(b >= 1);
    // Artifacts exist only for the manifest's batch sizes; pad a partial
    // batch (e.g. 3 requests with {1,4} compiled) by replicating the first
    // prompt — the filler lanes' outputs are discarded below.
    let exec_b = engine
        .manifest
        .batch_sizes
        .iter()
        .copied()
        .filter(|&n| n >= b)
        .min()
        .ok_or_else(|| anyhow::anyhow!(
            "batch {b} exceeds the largest compiled batch size"))?;
    let max_total = engine.manifest.largest_bucket();
    let mut seqs: Vec<Vec<u32>> = prompts.to_vec();
    while seqs.len() < exec_b {
        seqs.push(prompts[0].clone());
    }
    let mut done = vec![false; b];
    let mut out: Vec<BatchItemOutcome> = (0..b)
        .map(|_| BatchItemOutcome { tokens: vec![], target_calls: 0, real_s: 0.0, sim_s: 0.0 })
        .collect();

    for _ in 0..max_new {
        if done.iter().all(|&d| d) {
            break;
        }
        let longest = seqs.iter().map(Vec::len).max().unwrap();
        if longest + 1 > max_total {
            break;
        }
        let bucket = engine.bucket_for(longest)?;
        let views: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
        let fwd = engine.forward_batch(target, kernel, &views, bucket)?;
        let sim = sim_forward(bucket, b);
        // Filler lanes (i >= b) track lane 0 but produce no outcome.
        for i in b..exec_b {
            if !done[0] {
                let pos = seqs[i].len() - 1;
                let nxt = fwd.argmax(i, pos);
                if nxt != EOS_ID && seqs[i].len() + 1 < max_total {
                    seqs[i].push(nxt);
                }
            }
        }
        for i in 0..b {
            out[i].real_s += fwd.elapsed_s / b as f64;
            out[i].sim_s += sim / b as f64;
            if done[i] {
                continue;
            }
            out[i].target_calls += 1;
            let pos = seqs[i].len() - 1;
            let nxt = fwd.argmax(i, pos);
            if nxt == EOS_ID || seqs[i].len() + 1 >= max_total {
                done[i] = true;
                continue;
            }
            seqs[i].push(nxt);
            out[i].tokens.push(nxt);
        }
    }
    Ok(out)
}
