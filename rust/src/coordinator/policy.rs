//! Adaptive routing policy: the cost model applied *online*.
//!
//! The paper's workflow decides (speculation?, mapping, γ) offline from
//! profiled (α, c). A serving system can do better: the router keeps a
//! per-task running estimate of α (EWMA over per-request acceptance rates)
//! and re-evaluates Eq. (1) per request, so a task whose drafts keep getting
//! rejected automatically falls back to plain autoregressive decoding —
//! exactly the "naive adoption can increase latency" failure mode the paper
//! warns about, handled at runtime. (Extension beyond the paper; ablated in
//! the router bench.)
//!
//! With resumable sessions the policy is additionally consulted *between
//! speculation rounds* ([`Policy::route_round`]): the live session's own
//! acceptance evidence is blended with the task EWMA, so γ can shrink —
//! or speculation switch off entirely — midway through a request whose
//! drafts turn out worse than the admission-time estimate.

use crate::config::RunConfig;
use crate::costmodel;
use crate::hetero::{LatencyModel, Mapping, Platform};
use crate::models::{Scheme, VariantKey};
use std::collections::HashMap;
use std::sync::Mutex;

/// Per-request routing decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteDecision {
    pub speculative: bool,
    pub gamma: usize,
    pub mapping: Mapping,
    /// Predicted speedup at decision time (diagnostics).
    pub predicted_speedup: f64,
    /// The α estimate the decision used.
    pub alpha_used: f64,
}

/// Shared routing policy.
pub struct Policy {
    lat: LatencyModel,
    fixed_gamma: Option<usize>,
    speculative_enabled: bool,
    adaptive: bool,
    mapping: Mapping,
    drafter: VariantKey,
    target: VariantKey,
    /// Per-task EWMA of acceptance rate.
    alpha: Mutex<HashMap<String, f64>>,
    /// Optimistic prior before any observation (the paper's p90 α).
    prior_alpha: f64,
    ewma: f64,
}

impl Policy {
    pub fn new(cfg: &RunConfig, platform: Platform) -> Policy {
        let mapping = if cfg.heterogeneous {
            Mapping::heterogeneous(cfg.design_variant)
        } else {
            Mapping::homogeneous(cfg.design_variant)
        };
        Policy {
            lat: LatencyModel::new(platform),
            fixed_gamma: cfg.gamma,
            speculative_enabled: cfg.speculative,
            adaptive: cfg.gamma.is_none(),
            mapping,
            drafter: VariantKey::parse("drafter_fp").unwrap(),
            target: VariantKey::parse("target_w8a8").unwrap(),
            alpha: Mutex::new(HashMap::new()),
            prior_alpha: 0.90,
            ewma: 0.2,
        }
    }

    pub fn variants(&self) -> (VariantKey, VariantKey) {
        (self.drafter, self.target)
    }

    pub fn latency_model(&self) -> &LatencyModel {
        &self.lat
    }

    /// Current α estimate for a task.
    pub fn alpha_estimate(&self, task: &str) -> f64 {
        self.alpha
            .lock()
            .unwrap()
            .get(task)
            .copied()
            .unwrap_or(self.prior_alpha)
    }

    /// Decide the execution plan for one request at admission.
    pub fn route(
        &self,
        task: &str,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        seq_len: usize,
    ) -> RouteDecision {
        self.decide(self.alpha_estimate(task), d_spec, t_spec, seq_len)
    }

    /// Re-decide the plan between speculation rounds of a live session.
    ///
    /// `session_drafted` / `session_alpha` are the session's own running
    /// draft count and acceptance rate; once the session has real evidence
    /// its α dominates the task-level EWMA (weight grows with the sample
    /// count), so a request whose drafts collapse mid-flight falls back to
    /// baseline within that request — not merely for the next one.
    pub fn route_round(
        &self,
        task: &str,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        seq_len: usize,
        session_drafted: usize,
        session_alpha: f64,
    ) -> RouteDecision {
        let task_alpha = self.alpha_estimate(task);
        let alpha = if self.adaptive && session_drafted > 0 && session_alpha.is_finite() {
            let n = session_drafted as f64;
            let w = (n / (n + 8.0)).min(0.9);
            w * session_alpha + (1.0 - w) * task_alpha
        } else {
            task_alpha
        };
        self.decide(alpha, d_spec, t_spec, seq_len)
    }

    fn decide(
        &self,
        alpha: f64,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        seq_len: usize,
    ) -> RouteDecision {
        if !self.speculative_enabled {
            return RouteDecision {
                speculative: false,
                gamma: 0,
                mapping: self.mapping,
                predicted_speedup: 1.0,
                alpha_used: f64::NAN,
            };
        }
        let c = self.lat.cost_coefficient(
            (d_spec, Scheme::Fp),
            (t_spec, Scheme::W8a8),
            self.mapping,
            seq_len,
        );
        if let Some(g) = self.fixed_gamma {
            // Fixed-γ mode: still predict the speedup for diagnostics.
            return RouteDecision {
                speculative: true,
                gamma: g,
                mapping: self.mapping,
                predicted_speedup: costmodel::speedup(alpha, g, c),
                alpha_used: alpha,
            };
        }
        let choice = costmodel::optimal_gamma(alpha, c);
        RouteDecision {
            speculative: choice.gamma > 0,
            gamma: choice.gamma,
            mapping: self.mapping,
            predicted_speedup: choice.speedup,
            alpha_used: alpha,
        }
    }

    /// Cost-model prediction of the cross-PU overlap fraction the per-PU
    /// timelines should approach for a γ decided at `seq_len` under this
    /// policy's *own* mapping (0 for homogeneous mappings — there is only
    /// one timeline to occupy). Serving-side twin of the bound the
    /// `overlap` experiment evaluates at its explicit mapping via
    /// [`costmodel::predicted_overlap_frac`]: compare it against the live
    /// `Report::overlap_frac` to see whether co-scheduling is dense
    /// enough to realize the mapping's predicted concurrency.
    pub fn predicted_overlap(
        &self,
        d_spec: &crate::models::ModelSpec,
        t_spec: &crate::models::ModelSpec,
        gamma: usize,
        seq_len: usize,
    ) -> f64 {
        if !self.mapping.is_heterogeneous() {
            return 0.0;
        }
        let c = self.lat.cost_coefficient(
            (d_spec, Scheme::Fp),
            (t_spec, Scheme::W8a8),
            self.mapping,
            seq_len,
        );
        costmodel::predicted_overlap_frac(gamma as f64, c)
    }

    /// Feed back an observed per-request acceptance rate.
    pub fn observe_alpha(&self, task: &str, observed: f64) {
        if !observed.is_finite() || !self.adaptive {
            return;
        }
        let mut m = self.alpha.lock().unwrap();
        let e = m.entry(task.to_string()).or_insert(self.prior_alpha);
        *e = (1.0 - self.ewma) * *e + self.ewma * observed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelSpec;

    fn specs() -> (ModelSpec, ModelSpec) {
        (
            ModelSpec {
                name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
                ffn_dim: 256, vocab: 48, param_count: 230_880,
            },
            ModelSpec {
                name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
                ffn_dim: 352, vocab: 48, param_count: 816_256,
            },
        )
    }

    fn policy(cfg: &RunConfig) -> Policy {
        Policy::new(cfg, Platform::imx95())
    }

    #[test]
    fn optimistic_prior_speculates() {
        let cfg = RunConfig::default();
        let p = policy(&cfg);
        let (d, t) = specs();
        let dec = p.route("translate", &d, &t, 63);
        assert!(dec.speculative);
        assert!(dec.gamma >= 3, "{dec:?}");
        assert!(dec.predicted_speedup > 1.3);
    }

    #[test]
    fn low_alpha_task_falls_back_to_baseline() {
        let cfg = RunConfig::default();
        let p = policy(&cfg);
        let (d, t) = specs();
        // Hammer the estimate down with rejections.
        for _ in 0..60 {
            p.observe_alpha("hard-task", 0.05);
        }
        let dec = p.route("hard-task", &d, &t, 63);
        assert!(!dec.speculative, "{dec:?}");
        // Other tasks keep the optimistic prior.
        assert!(p.route("translate", &d, &t, 63).speculative);
    }

    #[test]
    fn fixed_gamma_respected() {
        let cfg = RunConfig { gamma: Some(2), ..RunConfig::default() };
        let p = policy(&cfg);
        let (d, t) = specs();
        let dec = p.route("translate", &d, &t, 63);
        assert!(dec.speculative);
        assert_eq!(dec.gamma, 2);
        // Fixed γ also disables adaptation.
        p.observe_alpha("translate", 0.0);
        assert!((p.alpha_estimate("translate") - 0.90).abs() < 1e-12);
    }

    #[test]
    fn speculation_disabled_routes_baseline() {
        let cfg = RunConfig { speculative: false, ..RunConfig::default() };
        let p = policy(&cfg);
        let (d, t) = specs();
        let dec = p.route("translate", &d, &t, 63);
        assert!(!dec.speculative);
        assert_eq!(dec.gamma, 0);
    }

    #[test]
    fn route_round_tracks_session_evidence() {
        let cfg = RunConfig::default();
        let p = policy(&cfg);
        let (d, t) = specs();
        // No evidence yet: identical to the admission decision.
        let admit = p.route("translate", &d, &t, 63);
        let r0 = p.route_round("translate", &d, &t, 63, 0, f64::NAN);
        assert_eq!(admit, r0);
        // A collapsing in-flight α must never pick a larger γ than a
        // perfect one, and with heavy evidence it dominates the prior.
        let bad = p.route_round("translate", &d, &t, 63, 64, 0.0);
        let good = p.route_round("translate", &d, &t, 63, 64, 1.0);
        assert!(bad.gamma <= good.gamma, "{bad:?} vs {good:?}");
        assert!(bad.alpha_used < admit.alpha_used);
        assert!(good.alpha_used > admit.alpha_used);
    }

    #[test]
    fn route_round_respects_global_off_switch() {
        let cfg = RunConfig { speculative: false, ..RunConfig::default() };
        let p = policy(&cfg);
        let (d, t) = specs();
        let dec = p.route_round("translate", &d, &t, 63, 10, 1.0);
        assert!(!dec.speculative);
        assert_eq!(dec.gamma, 0);
    }

    #[test]
    fn predicted_overlap_heterogeneous_only() {
        let (d, t) = specs();
        let het = policy(&RunConfig::default());
        let f = het.predicted_overlap(&d, &t, 5, 63);
        assert!(f > 0.0 && f <= 1.0, "{f}");
        // Homogeneous mapping: one timeline, nothing to overlap.
        let hom = policy(&RunConfig { heterogeneous: false, ..RunConfig::default() });
        assert_eq!(hom.predicted_overlap(&d, &t, 5, 63), 0.0);
        // No speculation, no draft/verify split.
        assert_eq!(het.predicted_overlap(&d, &t, 0, 63), 0.0);
    }

    #[test]
    fn ewma_converges() {
        let cfg = RunConfig::default();
        let p = policy(&cfg);
        for _ in 0..100 {
            p.observe_alpha("t", 0.5);
        }
        assert!((p.alpha_estimate("t") - 0.5).abs() < 0.01);
    }
}
