//! Routing policy — moved to [`crate::decision`], the unified decision
//! layer (cost-model trait, calibrated estimator, online re-partitioning).
//! Re-exported here so historical `coordinator::policy` paths keep
//! working.

pub use crate::decision::{Policy, RouteDecision, SpecHints};
