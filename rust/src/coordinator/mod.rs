//! The serving coordinator — Layer 3's vLLM-router-shaped core.
//!
//! * [`queue`] — bounded priority queue with backpressure (reject-on-full):
//!   `Interactive` before `Batch`, higher priority first, FIFO within a
//!   level; queued items carry cancel/deadline state so the worker sheds
//!   dead requests at admission
//! * [`policy`] — the routing [`Policy`] (now the decision engine in
//!   [`crate::decision`]): per-task α estimates feed the configured cost
//!   model (analytic or calibrated), which picks speculation on/off and
//!   γ* — at admission *and again between every speculation round* of a
//!   live session, clamped against the request's advisory
//!   [`SpecHints`](crate::decision::SpecHints) — and, in calibrated mode,
//!   periodically re-partitions the mapping for future admissions
//! * [`fuser`] — the cross-session fused batch executor: every scheduler
//!   tick collects all live sessions' pending
//!   [`EngineRequest`](crate::spec::EngineRequest)s, dispatches each
//!   (variant, kernel, bucket, pu) group as one `Engine::forward_batch`
//!   call, scatters the logits rows back through the sessions' `apply`,
//!   and schedules every dispatch on the worker's per-PU timelines
//!   ([`crate::hetero::PuTimelines`]) so heterogeneous draft/verify
//!   dispatches overlap across co-scheduled sessions
//! * [`legacy_lockstep`] — quarantined pre-fuser static-batching
//!   reference (A/B baseline only; the serving path batches through
//!   [`fuser`])
//! * [`worker`] — engine worker threads (one PJRT engine each), each
//!   running a tick-level scheduler over up to `max_inflight` resumable
//!   [`DecodeSession`](crate::spec::DecodeSession)s
//!
//! Flow: client → [`Coordinator::submit`] → [`RequestHandle`] → queue →
//! worker (policy → fused session ticks) → token frames + final response;
//! metrics are recorded centrally per round, per dispatch and per request.
//!
//! **Request lifecycle (API v2).** `submit` takes one
//! [`GenerationRequest`] (a bare workload `Request` converts with default
//! options) and returns a [`RequestHandle`]: [`wait`](RequestHandle::wait)
//! for the final [`EngineResponse`], [`frames`](RequestHandle::frames) /
//! [`try_frame`](RequestHandle::try_frame) for round-by-round streaming,
//! [`cancel`](RequestHandle::cancel) to abort. Cancellation and deadline
//! expiry take effect at the next *round boundary* of the live session:
//! the scheduler slot frees immediately for queued work and the response
//! carries the tokens committed so far with a typed
//! [`FinishReason`](crate::api::FinishReason). Submission never blocks
//! and never errors: backpressure comes back through the handle as a
//! `Rejected` response.

pub mod fuser;
pub mod legacy_lockstep;
pub mod policy;
pub mod queue;
pub mod worker;

use crate::api::{FinishReason, GenerationRequest};
use crate::config::RunConfig;
use crate::hetero::Platform;
use crate::metrics::Metrics;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};

pub use policy::{Policy, RouteDecision, SpecHints};
pub use queue::{QueueItem, RequestQueue};

/// Response for one request.
#[derive(Debug, Clone)]
pub struct EngineResponse {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub completion: String,
    pub sim_s: f64,
    pub real_s: f64,
    pub queue_s: f64,
    pub alpha: f64,
    pub speculative: bool,
    /// γ decided at admission (per-round choices are in the metrics).
    pub gamma: usize,
    /// Scheduler rounds this request took (lockstep-batched baseline
    /// requests count one round per shared decode step).
    pub rounds: usize,
    /// Why the request ended (typed; `Rejected` responses carry no
    /// tokens, `Cancelled`/`DeadlineExceeded` carry the tokens committed
    /// before the round-boundary abort).
    pub finish: FinishReason,
}

impl EngineResponse {
    /// Response for a request that never decoded (rejection at submit,
    /// or shedding at admission).
    pub(crate) fn shed(id: u64, queue_s: f64, finish: FinishReason) -> EngineResponse {
        EngineResponse {
            id,
            tokens: Vec::new(),
            completion: String::new(),
            sim_s: 0.0,
            real_s: 0.0,
            queue_s,
            alpha: f64::NAN,
            speculative: false,
            gamma: 0,
            rounds: 0,
            finish,
        }
    }
}

/// One round's incremental output for a streaming request.
#[derive(Debug, Clone)]
pub struct TokenFrame {
    pub id: u64,
    /// 1-based scheduler round within this request.
    pub round: usize,
    /// Tokens newly committed by this round (may be empty on the final
    /// bookkeeping frame).
    pub tokens: Vec<u32>,
    /// Draft window this round ran and how much of it was accepted
    /// (both 0 on baseline steps and on the batched path).
    pub drafted: usize,
    pub accepted: usize,
    /// Last frame of the stream; the final [`EngineResponse`] follows on
    /// the response channel.
    pub done: bool,
}

/// Live-request cancellation flags, keyed by request id, so cancellation
/// can reach a request from *any* context (another connection's
/// `{"cmd":"cancel"}`, a different thread holding only the id). Entries
/// are removed by the [`CancelGuard`] when the request's queue item /
/// live session is dropped. Ids are a shared namespace and *should* be
/// unique; if a caller reuses a live id anyway, the entry holds every
/// matching flag and a cancel fires all of them (best-effort — no
/// request is ever left silently uncancellable).
#[derive(Default)]
pub struct CancelRegistry {
    inner: Mutex<HashMap<u64, Vec<Arc<AtomicBool>>>>,
}

impl CancelRegistry {
    fn register(&self, id: u64, flag: &Arc<AtomicBool>) {
        self.inner
            .lock()
            .unwrap()
            .entry(id)
            .or_default()
            .push(Arc::clone(flag));
    }

    /// Flag the request(s) under `id` cancelled; false when the id is
    /// unknown (never submitted, or already finished).
    pub fn cancel(&self, id: u64) -> bool {
        match self.inner.lock().unwrap().get(&id) {
            Some(flags) => {
                for f in flags {
                    f.store(true, Ordering::SeqCst);
                }
                !flags.is_empty()
            }
            None => false,
        }
    }

    /// Remove exactly this request's `flag` from `id`'s entry (a re-used
    /// id must not evict another live request's flag).
    fn remove(&self, id: u64, flag: &Arc<AtomicBool>) {
        let mut m = self.inner.lock().unwrap();
        if let Some(flags) = m.get_mut(&id) {
            flags.retain(|f| !Arc::ptr_eq(f, flag));
            if flags.is_empty() {
                m.remove(&id);
            }
        }
    }
}

/// A request's cancellation flag plus registry cleanup-on-drop. Travels
/// with the request through the queue into the worker's live set; when it
/// drops (request answered, or its channels torn down), the registry
/// entry goes with it.
pub struct CancelGuard {
    id: u64,
    flag: Arc<AtomicBool>,
    registry: Option<Arc<CancelRegistry>>,
}

impl CancelGuard {
    /// A flag registered with a coordinator's registry.
    fn registered(id: u64, flag: Arc<AtomicBool>, registry: Arc<CancelRegistry>) -> CancelGuard {
        registry.register(id, &flag);
        CancelGuard { id, flag, registry: Some(registry) }
    }

    /// A free-standing flag (tests, benches, drivers that never cancel).
    pub fn detached() -> CancelGuard {
        CancelGuard { id: 0, flag: Arc::new(AtomicBool::new(false)), registry: None }
    }

    pub fn cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    /// The underlying flag (shared with the request's [`RequestHandle`]).
    pub fn flag(&self) -> &Arc<AtomicBool> {
        &self.flag
    }
}

impl Drop for CancelGuard {
    fn drop(&mut self) {
        if let Some(reg) = &self.registry {
            reg.remove(self.id, &self.flag);
        }
    }
}

/// Caller-side handle for one submitted request: streaming frames, the
/// final response, and cancellation.
pub struct RequestHandle {
    id: u64,
    cancel: Arc<AtomicBool>,
    frames: mpsc::Receiver<TokenFrame>,
    response: mpsc::Receiver<EngineResponse>,
}

impl RequestHandle {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cancellation. Takes effect at the next round boundary of
    /// the live session (or at admission if still queued); the final
    /// response arrives with [`FinishReason::Cancelled`] and the tokens
    /// committed so far. Idempotent; a no-op after completion.
    pub fn cancel(&self) {
        self.cancel.store(true, Ordering::SeqCst);
    }

    /// Block for the final [`EngineResponse`]. Errors only if the worker
    /// died without answering (dropped channel).
    pub fn wait(&self) -> anyhow::Result<EngineResponse> {
        self.response
            .recv()
            .map_err(|_| anyhow::anyhow!("worker dropped the request"))
    }

    /// Non-blocking check for the final response.
    pub fn try_wait(&self) -> Option<EngineResponse> {
        self.response.try_recv().ok()
    }

    /// Non-blocking poll that distinguishes "not yet" (`None`) from
    /// "worker died without answering" (`Some(Err(_))`, mirroring
    /// [`wait`](Self::wait)'s error). Event-loop callers need the
    /// distinction: plain [`try_wait`](Self::try_wait) folds a dropped
    /// channel into `None`, which would poll forever.
    pub fn try_wait_done(&self) -> Option<anyhow::Result<EngineResponse>> {
        match self.response.try_recv() {
            Ok(r) => Some(Ok(r)),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => {
                Some(Err(anyhow::anyhow!("worker dropped the request")))
            }
        }
    }

    /// Non-blocking poll for the next streamed [`TokenFrame`].
    pub fn try_frame(&self) -> Option<TokenFrame> {
        self.frames.try_recv().ok()
    }

    /// Blocking iterator over streamed frames; ends when the request
    /// retires (after a frame with `done: true`, or immediately for
    /// requests that never decoded). [`wait`](Self::wait) afterwards for
    /// the final response.
    pub fn frames(&self) -> mpsc::Iter<'_, TokenFrame> {
        self.frames.iter()
    }
}

/// Running coordinator: queue + worker pool + metrics.
pub struct Coordinator {
    queue: Arc<RequestQueue>,
    pub metrics: Arc<Metrics>,
    pub policy: Arc<Policy>,
    cancels: Arc<CancelRegistry>,
    shutdown: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn `cfg.workers` engine workers and return the running coordinator.
    pub fn start(cfg: RunConfig, platform: Platform) -> anyhow::Result<Coordinator> {
        let queue = Arc::new(RequestQueue::new(cfg.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let policy = Arc::new(Policy::new(&cfg, platform.clone())?);
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        for wid in 0..cfg.workers {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            let policy = Arc::clone(&policy);
            let shutdown = Arc::clone(&shutdown);
            let cfg = cfg.clone();
            let platform = platform.clone();
            let ready = ready_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("engine-worker-{wid}"))
                    .spawn(move || {
                        worker::run_worker(
                            wid, cfg, platform, queue, metrics, policy, shutdown, ready,
                        );
                    })
                    .expect("spawn worker"),
            );
        }
        drop(ready_tx);
        // Wait for every worker's engine to come up (or fail fast).
        for _ in 0..cfg.workers {
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker died during startup"))??;
        }
        Ok(Coordinator {
            queue,
            metrics,
            policy,
            cancels: Arc::new(CancelRegistry::default()),
            shutdown,
            handles,
        })
    }

    /// Submit one request (a bare workload `Request` converts with
    /// default [`GenOptions`](crate::api::GenOptions)) and get its
    /// [`RequestHandle`]. Never blocks, never errors: on backpressure
    /// (queue full or shutting down) the handle resolves immediately to
    /// a [`FinishReason::Rejected`] response with no tokens.
    pub fn submit(&self, req: impl Into<GenerationRequest>) -> RequestHandle {
        let req: GenerationRequest = req.into();
        let id = req.id;
        let (ftx, frx) = mpsc::channel();
        let (tx, rx) = mpsc::channel();
        let guard = CancelGuard::registered(
            id,
            Arc::new(AtomicBool::new(false)),
            Arc::clone(&self.cancels),
        );
        let handle = RequestHandle {
            id,
            cancel: Arc::clone(guard.flag()),
            frames: frx,
            response: rx,
        };
        let slo = req.options.slo;
        let had_deadline = req.options.deadline_s.is_some();
        let item = QueueItem::with_cancel(req, tx, Some(ftx), guard);
        if let Err(item) = self.queue.push(item) {
            // Backpressure (or closed): answer through the handle so every
            // submission resolves to a typed FinishReason. Dropping the
            // item's frame sender ends the (empty) frame stream.
            self.metrics.record_rejected();
            self.metrics.record_finish(FinishReason::Rejected);
            self.metrics.record_slo(slo);
            if had_deadline {
                // A deadline-carrying request bounced by backpressure
                // missed its deadline — overload is exactly when the
                // miss rate must not read low.
                self.metrics.record_deadline(true);
            }
            let _ = item
                .respond
                .send(EngineResponse::shed(id, 0.0, FinishReason::Rejected));
        }
        handle
    }

    /// Cancel a request by id (the cross-context path — the v2 wire
    /// protocol's `{"cmd":"cancel"}` lands here). Returns false for
    /// unknown/already-finished ids. Same round-boundary semantics as
    /// [`RequestHandle::cancel`].
    pub fn cancel(&self, id: u64) -> bool {
        self.cancels.cancel(id)
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn queue_capacity(&self) -> usize {
        self.queue.capacity()
    }
}
