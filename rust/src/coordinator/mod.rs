//! The serving coordinator — Layer 3's vLLM-router-shaped core.
//!
//! * [`queue`] — bounded request queue with backpressure (reject-on-full)
//! * [`policy`] — the routing [`Policy`] (now the decision engine in
//!   [`crate::decision`]): per-task α estimates feed the configured cost
//!   model (analytic or calibrated), which picks speculation on/off and
//!   γ* — at admission *and again between every speculation round* of a
//!   live session — and, in calibrated mode, periodically re-partitions
//!   the mapping for future admissions
//! * [`fuser`] — the cross-session fused batch executor: every scheduler
//!   tick collects all live sessions' pending
//!   [`EngineRequest`](crate::spec::EngineRequest)s, dispatches each
//!   (variant, kernel, bucket, pu) group as one `Engine::forward_batch`
//!   call, scatters the logits rows back through the sessions' `apply`,
//!   and schedules every dispatch on the worker's per-PU timelines
//!   ([`crate::hetero::PuTimelines`]) so heterogeneous draft/verify
//!   dispatches overlap across co-scheduled sessions
//! * [`batcher`] — the legacy lockstep static-batching reference (the
//!   serving path now batches through [`fuser`] instead)
//! * [`worker`] — engine worker threads (one PJRT engine each), each
//!   running a tick-level scheduler over up to `max_inflight` resumable
//!   [`DecodeSession`](crate::spec::DecodeSession)s
//!
//! Flow: client → [`Coordinator::submit`] / [`Coordinator::submit_streaming`]
//! → queue → worker (policy → fused session ticks) → token frames + final
//! response; metrics are recorded centrally per round, per dispatch and
//! per request.

pub mod batcher;
pub mod fuser;
pub mod policy;
pub mod queue;
pub mod worker;

use crate::config::RunConfig;
use crate::hetero::Platform;
use crate::metrics::Metrics;
use crate::workload::Request;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

pub use policy::{Policy, RouteDecision};
pub use queue::{QueueItem, RequestQueue};

/// Response for one request.
#[derive(Debug, Clone)]
pub struct EngineResponse {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub completion: String,
    pub sim_s: f64,
    pub real_s: f64,
    pub queue_s: f64,
    pub alpha: f64,
    pub speculative: bool,
    /// γ decided at admission (per-round choices are in the metrics).
    pub gamma: usize,
    /// Scheduler rounds this request took (0 on the batched path).
    pub rounds: usize,
}

/// One round's incremental output for a streaming request.
#[derive(Debug, Clone)]
pub struct TokenFrame {
    pub id: u64,
    /// 1-based scheduler round within this request.
    pub round: usize,
    /// Tokens newly committed by this round (may be empty on the final
    /// bookkeeping frame).
    pub tokens: Vec<u32>,
    /// Draft window this round ran and how much of it was accepted
    /// (both 0 on baseline steps and on the batched path).
    pub drafted: usize,
    pub accepted: usize,
    /// Last frame of the stream; the final [`EngineResponse`] follows on
    /// the response channel.
    pub done: bool,
}

/// Running coordinator: queue + worker pool + metrics.
pub struct Coordinator {
    queue: Arc<RequestQueue>,
    pub metrics: Arc<Metrics>,
    pub policy: Arc<Policy>,
    shutdown: Arc<AtomicBool>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn `cfg.workers` engine workers and return the running coordinator.
    pub fn start(cfg: RunConfig, platform: Platform) -> anyhow::Result<Coordinator> {
        let queue = Arc::new(RequestQueue::new(cfg.queue_capacity));
        let metrics = Arc::new(Metrics::new());
        let policy = Arc::new(Policy::new(&cfg, platform.clone())?);
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        for wid in 0..cfg.workers {
            let queue = Arc::clone(&queue);
            let metrics = Arc::clone(&metrics);
            let policy = Arc::clone(&policy);
            let shutdown = Arc::clone(&shutdown);
            let cfg = cfg.clone();
            let platform = platform.clone();
            let ready = ready_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("engine-worker-{wid}"))
                    .spawn(move || {
                        worker::run_worker(
                            wid, cfg, platform, queue, metrics, policy, shutdown, ready,
                        );
                    })
                    .expect("spawn worker"),
            );
        }
        drop(ready_tx);
        // Wait for every worker's engine to come up (or fail fast).
        for _ in 0..cfg.workers {
            ready_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker died during startup"))??;
        }
        Ok(Coordinator { queue, metrics, policy, shutdown, handles })
    }

    /// Submit a request; returns the response receiver, or Err on
    /// backpressure (queue full).
    pub fn submit(
        &self,
        req: Request,
    ) -> anyhow::Result<mpsc::Receiver<EngineResponse>> {
        self.enqueue(req, None)
    }

    /// Submit with incremental output: tokens arrive round-by-round on the
    /// frame receiver as the scheduler commits them, then the final
    /// [`EngineResponse`] on the response receiver.
    pub fn submit_streaming(
        &self,
        req: Request,
    ) -> anyhow::Result<(mpsc::Receiver<TokenFrame>, mpsc::Receiver<EngineResponse>)> {
        let (ftx, frx) = mpsc::channel();
        let rx = self.enqueue(req, Some(ftx))?;
        Ok((frx, rx))
    }

    fn enqueue(
        &self,
        req: Request,
        token_tx: Option<mpsc::Sender<TokenFrame>>,
    ) -> anyhow::Result<mpsc::Receiver<EngineResponse>> {
        let (tx, rx) = mpsc::channel();
        let item = QueueItem {
            request: req,
            enqueued: std::time::Instant::now(),
            respond: tx,
            token_tx,
        };
        match self.queue.push(item) {
            Ok(()) => Ok(rx),
            Err(_) => {
                self.metrics.record_rejected();
                anyhow::bail!("queue full (backpressure)")
            }
        }
    }

    /// Convenience: submit and block for the response.
    pub fn submit_blocking(&self, req: Request) -> anyhow::Result<EngineResponse> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("worker dropped the request"))
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }
}
