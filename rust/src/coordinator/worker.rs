//! Engine worker: owns one PJRT engine (the xla wrapper types are not
//! `Send`, so the engine lives and dies inside this thread) and runs a
//! round-level continuous scheduler over the shared queue until shutdown.
//!
//! Instead of occupying the thread with one request until completion, the
//! worker keeps up to `cfg.max_inflight` live [`DecodeSession`]s and steps
//! each one speculation round at a time, round-robin:
//!
//! 1. **admit** — top the in-flight set up from the queue (blocking only
//!    when nothing is live);
//! 2. **consult** — re-run the routing [`Policy`] for every live session,
//!    so γ and speculate-on/off are re-decided per round from the
//!    session's running α (the cost model in the hot loop);
//! 3. **step** — advance each session one round, stream newly committed
//!    tokens to the request's `token_tx`, record per-round metrics;
//! 4. **retire** — finished sessions emit their final [`EngineResponse`].
//!
//! The legacy lockstep batcher still handles the `max_batch > 1` baseline
//! configuration (it decodes whole batches, so it bypasses the scheduler).

use crate::config::RunConfig;
use crate::hetero::{LatencyModel, Platform};
use crate::metrics::{Metrics, RequestRecord, RoundRecord};
use crate::models::ModelSpec;
use crate::runtime::Engine;
use crate::spec::{AcceptRule, DecodeSession, DecoderSetup};
use crate::tokenizer::Tokenizer;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use super::batcher;
use super::policy::Policy;
use super::queue::{QueueItem, RequestQueue};
use super::{EngineResponse, TokenFrame};

/// One live request inside the worker's scheduler.
struct LiveSession {
    session: DecodeSession,
    respond: mpsc::Sender<EngineResponse>,
    token_tx: Option<mpsc::Sender<TokenFrame>>,
    id: u64,
    task: String,
    /// Queue delay, measured at admission.
    queue_s: f64,
    /// Admission-time decision (reported in the final response).
    admitted_speculative: bool,
    admitted_gamma: usize,
    rounds: usize,
}

/// Worker main loop (runs on its own thread).
#[allow(clippy::too_many_arguments)]
pub fn run_worker(
    wid: usize,
    cfg: RunConfig,
    platform: Platform,
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    policy: Arc<Policy>,
    shutdown: Arc<AtomicBool>,
    ready: mpsc::Sender<anyhow::Result<()>>,
) {
    // Build the engine inside the thread; report readiness (or the error).
    let engine = match Engine::load(&cfg.artifacts_dir) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow::anyhow!("worker {wid}: {e}")));
            return;
        }
    };
    let tokenizer = match Tokenizer::from_manifest(&engine.manifest.tokenizer_spec) {
        Ok(t) => t,
        Err(_) => Tokenizer::builtin(),
    };
    let (drafter, target) = policy.variants();
    // Warm the executable cache so first requests don't pay compile time.
    let buckets: Vec<usize> = engine.manifest.seq_buckets.clone();
    let _ = engine.warmup(&[drafter, target], cfg.kernel_path, &buckets);

    let lat = LatencyModel::new(platform);
    let (d_spec, t_spec) = match (
        engine.manifest.model_for(drafter).cloned(),
        engine.manifest.model_for(target).cloned(),
    ) {
        (Ok(d), Ok(t)) => (d, t),
        _ => {
            // Malformed manifest: drain the queue until shutdown so every
            // waiting caller sees its response sender dropped (RecvError)
            // instead of blocking forever on an unserved request.
            while queue.pop().is_some() {}
            return;
        }
    };

    // The lockstep batcher owns the baseline-batching configuration; lone
    // requests under low traffic still decode on the session path (the
    // Pallas batch-1 artifacts), exactly as before batching kicked in.
    if cfg.max_batch > 1 && !cfg.speculative {
        while !shutdown.load(Ordering::SeqCst) {
            let batch = queue.pop_batch(cfg.max_batch);
            if batch.is_empty() {
                break; // queue closed
            }
            if batch.len() == 1 {
                let item = batch.into_iter().next().unwrap();
                let ls = admit(&cfg, &engine, &lat, &policy, &d_spec, &t_spec,
                               item, drafter, target);
                serve_single(&engine, &policy, &metrics, &tokenizer,
                             &d_spec, &t_spec, ls);
            } else {
                serve_batch(&cfg, &engine, &lat, &tokenizer, &metrics, batch, target);
            }
        }
        return;
    }

    let max_inflight = cfg.max_inflight.max(1);
    let mut live: Vec<LiveSession> = Vec::new();
    let mut queue_open = true;

    loop {
        // ---- admit: top up the in-flight set -------------------------
        // On shutdown, stop admitting but finish the (bounded) in-flight
        // set — the old loop's "complete the current request" semantics.
        while queue_open && !shutdown.load(Ordering::SeqCst) && live.len() < max_inflight {
            let item = if live.is_empty() {
                // Nothing to step: block until work arrives or close.
                match queue.pop() {
                    Some(i) => i,
                    None => {
                        queue_open = false;
                        break;
                    }
                }
            } else {
                match queue.try_pop() {
                    Some(i) => i,
                    None => break,
                }
            };
            live.push(admit(&cfg, &engine, &lat, &policy, &d_spec, &t_spec,
                            item, drafter, target));
        }
        if live.is_empty() {
            if !queue_open || shutdown.load(Ordering::SeqCst) {
                break;
            }
            continue;
        }

        // ---- consult + step every live session one round -------------
        let inflight_now = live.len();
        let mut i = 0;
        while i < live.len() {
            match step_session(&engine, &policy, &metrics, &d_spec, &t_spec,
                               &mut live[i], inflight_now) {
                None => {
                    // Dropping the sender(s) signals the error to the caller.
                    live.remove(i);
                }
                Some(true) => {
                    let ls = live.remove(i);
                    retire(&tokenizer, &metrics, &policy, ls);
                }
                Some(false) => i += 1,
            }
        }
    }
}

/// Drive one admitted session to completion — the scheduler path
/// specialized to a single in-flight session (used by the batched config
/// for lone requests, so low traffic keeps the normal kernel/streaming/
/// metrics behavior).
fn serve_single(
    engine: &Engine,
    policy: &Policy,
    metrics: &Metrics,
    tokenizer: &Tokenizer,
    d_spec: &ModelSpec,
    t_spec: &ModelSpec,
    mut ls: LiveSession,
) {
    loop {
        match step_session(engine, policy, metrics, d_spec, t_spec, &mut ls, 1) {
            None => break, // dropped senders signal the error
            Some(true) => {
                retire(tokenizer, metrics, policy, ls);
                break;
            }
            Some(false) => {}
        }
    }
}

/// Consult the policy, advance one round, record it, and stream any newly
/// committed tokens. Returns `Some(done)`, or `None` when the step failed
/// and the session should be dropped.
fn step_session(
    engine: &Engine,
    policy: &Policy,
    metrics: &Metrics,
    d_spec: &ModelSpec,
    t_spec: &ModelSpec,
    ls: &mut LiveSession,
    inflight_now: usize,
) -> Option<bool> {
    // Round-level policy: γ and speculate-on/off re-decided from the
    // session's running α before every round.
    let dec = policy.route_round(
        &ls.task, d_spec, t_spec, ls.session.seq_len(),
        ls.session.n_drafted(), ls.session.alpha_so_far(),
    );
    ls.session.set_speculative(dec.speculative);
    if dec.speculative {
        // Artifact-aware: monolithic fused graphs only exist for the γs
        // the AOT build lowered, so the serving path clamps.
        ls.session.set_gamma_checked(engine, dec.gamma);
    }

    let step = ls.session.step(engine).ok()?;
    ls.rounds += 1;
    // Bookkeeping steps that only discovered completion (born-finished
    // cap==0 sessions, bucket-edge termination) ran no engine work and
    // would dilute the per-round metrics.
    let worked = step.drafted > 0 || !step.committed.is_empty() || step.sim_s > 0.0;
    if worked {
        metrics.record_round(RoundRecord {
            drafted: step.drafted,
            accepted: step.accepted,
            sim_s: step.sim_s,
            real_s: step.real_s,
            inflight: inflight_now,
        });
    }
    if let Some(tx) = &ls.token_tx {
        if !step.committed.is_empty() || step.done {
            let _ = tx.send(TokenFrame {
                id: ls.id,
                round: ls.rounds,
                tokens: step.committed,
                drafted: step.drafted,
                accepted: step.accepted,
                done: step.done,
            });
        }
    }
    Some(step.done)
}

/// Route one queue item and wrap it into a live session.
#[allow(clippy::too_many_arguments)]
fn admit(
    cfg: &RunConfig,
    engine: &Engine,
    lat: &LatencyModel,
    policy: &Policy,
    d_spec: &ModelSpec,
    t_spec: &ModelSpec,
    item: QueueItem,
    drafter: crate::models::VariantKey,
    target: crate::models::VariantKey,
) -> LiveSession {
    let queue_s = item.enqueued.elapsed().as_secs_f64();
    let req = item.request;
    let decision = policy.route(&req.task, d_spec, t_spec, req.prompt.len());
    let setup = DecoderSetup {
        drafter,
        target,
        kernel: cfg.kernel_path,
        mapping: decision.mapping,
        gamma: decision.gamma.max(1),
        rule: AcceptRule::Greedy,
        exec: cfg.exec_mode,
        max_new: cfg.max_new_tokens,
    };
    let session =
        DecodeSession::new(engine, lat.clone(), setup, decision.speculative, &req.prompt);
    LiveSession {
        session,
        respond: item.respond,
        token_tx: item.token_tx,
        id: req.id,
        task: req.task,
        queue_s,
        admitted_speculative: decision.speculative,
        admitted_gamma: decision.gamma,
        rounds: 0,
    }
}

/// Account for and answer one finished session.
fn retire(tokenizer: &Tokenizer, metrics: &Metrics, policy: &Policy, ls: LiveSession) {
    let outcome = ls.session.into_outcome();
    policy.observe_alpha(&ls.task, outcome.alpha());
    metrics.record(RequestRecord {
        sim_s: outcome.sim_s,
        real_s: outcome.real_s,
        queue_s: ls.queue_s,
        tokens: outcome.tokens.len(),
        drafted: outcome.n_drafted,
        accepted: outcome.n_accepted,
    });
    let completion = tokenizer.decode(&outcome.tokens);
    let alpha = outcome.alpha();
    let _ = ls.respond.send(EngineResponse {
        id: ls.id,
        completion,
        tokens: outcome.tokens,
        sim_s: outcome.sim_s,
        real_s: outcome.real_s,
        queue_s: ls.queue_s,
        alpha,
        speculative: ls.admitted_speculative,
        gamma: ls.admitted_gamma,
        rounds: ls.rounds,
    });
}

fn serve_batch(
    cfg: &RunConfig,
    engine: &Engine,
    lat: &LatencyModel,
    tokenizer: &Tokenizer,
    metrics: &Metrics,
    batch: Vec<QueueItem>,
    target: crate::models::VariantKey,
) {
    let t_spec = match engine.manifest.model_for(target) {
        Ok(s) => s.clone(),
        Err(_) => return,
    };
    let mapping = if cfg.heterogeneous {
        crate::hetero::Mapping::heterogeneous(cfg.design_variant)
    } else {
        crate::hetero::Mapping::homogeneous(cfg.design_variant)
    };
    let prompts: Vec<Vec<u32>> = batch.iter().map(|i| i.request.prompt.clone()).collect();
    let lat = lat.clone();
    let t_scheme = target.scheme;
    let sim_forward = move |bucket: usize, b: usize| {
        // Batched forward ~ b× the single-sequence FLOPs on the same PU
        // (no batching win on a saturated edge CPU), one dispatch boundary.
        let single = lat.forward_latency(&t_spec, t_scheme, mapping.target, bucket);
        let oh = match mapping.target {
            crate::hetero::PuAssignment::Cpu { .. } => lat.platform.cpu.dispatch_overhead_s,
            crate::hetero::PuAssignment::Gpu => lat.platform.gpu.dispatch_overhead_s,
        };
        (single - oh) * b as f64 + oh
    };
    // Batched artifacts exist only for the ref lowering (the Pallas path is
    // the batch-1 latency path; see aot.py) — batch decode always uses Ref.
    let outcomes = match batcher::batched_baseline(
        engine, target, crate::config::KernelPath::Ref, &prompts,
        cfg.max_new_tokens, &sim_forward,
    ) {
        Ok(o) => o,
        Err(_) => return,
    };
    for (item, o) in batch.into_iter().zip(outcomes) {
        let queue_s = item.enqueued.elapsed().as_secs_f64();
        metrics.record(RequestRecord {
            sim_s: o.sim_s,
            real_s: o.real_s,
            queue_s,
            tokens: o.tokens.len(),
            drafted: 0,
            accepted: 0,
        });
        // Lockstep batching has no per-round commits; streaming callers
        // still get their terminating done-frame with the full output.
        if let Some(tx) = &item.token_tx {
            let _ = tx.send(TokenFrame {
                id: item.request.id,
                round: 1,
                tokens: o.tokens.clone(),
                drafted: 0,
                accepted: 0,
                done: true,
            });
        }
        let _ = item.respond.send(EngineResponse {
            id: item.request.id,
            completion: tokenizer.decode(&o.tokens),
            tokens: o.tokens,
            sim_s: o.sim_s,
            real_s: o.real_s,
            queue_s,
            alpha: f64::NAN,
            speculative: false,
            gamma: 0,
            rounds: 0,
        });
    }
}
