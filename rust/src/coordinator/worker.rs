//! Engine worker: owns one PJRT engine (the xla wrapper types are not
//! `Send`, so the engine lives and dies inside this thread) and serves
//! requests from the shared queue until shutdown.

use crate::config::RunConfig;
use crate::hetero::{LatencyModel, Platform};
use crate::metrics::{Metrics, RequestRecord};
use crate::runtime::Engine;
use crate::spec::{AcceptRule, Decoder, DecoderSetup};
use crate::tokenizer::Tokenizer;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use super::batcher;
use super::policy::Policy;
use super::queue::{QueueItem, RequestQueue};
use super::EngineResponse;

/// Worker main loop (runs on its own thread).
#[allow(clippy::too_many_arguments)]
pub fn run_worker(
    wid: usize,
    cfg: RunConfig,
    platform: Platform,
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    policy: Arc<Policy>,
    shutdown: Arc<AtomicBool>,
    ready: mpsc::Sender<anyhow::Result<()>>,
) {
    // Build the engine inside the thread; report readiness (or the error).
    let engine = match Engine::load(&cfg.artifacts_dir) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow::anyhow!("worker {wid}: {e}")));
            return;
        }
    };
    let tokenizer = match Tokenizer::from_manifest(&engine.manifest.tokenizer_spec) {
        Ok(t) => t,
        Err(_) => Tokenizer::builtin(),
    };
    let (drafter, target) = policy.variants();
    // Warm the executable cache so first requests don't pay compile time.
    let buckets: Vec<usize> = engine.manifest.seq_buckets.clone();
    let _ = engine.warmup(&[drafter, target], cfg.kernel_path, &buckets);

    let lat = LatencyModel::new(platform);

    while !shutdown.load(Ordering::SeqCst) {
        // Batch only when configured AND speculation is globally off (the
        // batcher handles baseline decode only — see batcher docs).
        let batch = if cfg.max_batch > 1 && !cfg.speculative {
            queue.pop_batch(cfg.max_batch)
        } else {
            match queue.pop() {
                Some(i) => vec![i],
                None => break,
            }
        };
        if batch.is_empty() {
            break; // queue closed
        }
        if batch.len() > 1 {
            serve_batch(&cfg, &engine, &lat, &tokenizer, &metrics, batch, target);
        } else {
            let item = batch.into_iter().next().unwrap();
            serve_one(&cfg, &engine, &lat, &tokenizer, &metrics, &policy, item,
                      drafter, target);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_one(
    cfg: &RunConfig,
    engine: &Engine,
    lat: &LatencyModel,
    tokenizer: &Tokenizer,
    metrics: &Metrics,
    policy: &Policy,
    item: QueueItem,
    drafter: crate::models::VariantKey,
    target: crate::models::VariantKey,
) {
    let queue_s = item.enqueued.elapsed().as_secs_f64();
    let req = item.request;
    let d_spec = engine.manifest.model_for(drafter).cloned();
    let t_spec = engine.manifest.model_for(target).cloned();
    let (d_spec, t_spec) = match (d_spec, t_spec) {
        (Ok(d), Ok(t)) => (d, t),
        _ => return,
    };
    let decision = policy.route(&req.task, &d_spec, &t_spec, req.prompt.len());

    let setup = DecoderSetup {
        drafter,
        target,
        kernel: cfg.kernel_path,
        mapping: decision.mapping,
        gamma: decision.gamma.max(1),
        rule: AcceptRule::Greedy,
        exec: cfg.exec_mode,
        max_new: cfg.max_new_tokens,
    };
    let decoder = Decoder::new(engine, lat.clone(), setup);
    let outcome = if decision.speculative {
        decoder.speculative(&req.prompt)
    } else {
        decoder.baseline(&req.prompt)
    };
    let outcome = match outcome {
        Ok(o) => o,
        Err(_) => return, // dropped sender signals the error to the caller
    };
    policy.observe_alpha(&req.task, outcome.alpha());
    metrics.record(RequestRecord {
        sim_s: outcome.sim_s,
        real_s: outcome.real_s,
        queue_s,
        tokens: outcome.tokens.len(),
        drafted: outcome.n_drafted,
        accepted: outcome.n_accepted,
    });
    let completion = tokenizer.decode(&outcome.tokens);
    let alpha = outcome.alpha();
    let _ = item.respond.send(EngineResponse {
        id: req.id,
        completion,
        tokens: outcome.tokens,
        sim_s: outcome.sim_s,
        real_s: outcome.real_s,
        queue_s,
        alpha,
        speculative: decision.speculative,
        gamma: decision.gamma,
    });
}

fn serve_batch(
    cfg: &RunConfig,
    engine: &Engine,
    lat: &LatencyModel,
    tokenizer: &Tokenizer,
    metrics: &Metrics,
    batch: Vec<QueueItem>,
    target: crate::models::VariantKey,
) {
    let t_spec = match engine.manifest.model_for(target) {
        Ok(s) => s.clone(),
        Err(_) => return,
    };
    let mapping = if cfg.heterogeneous {
        crate::hetero::Mapping::heterogeneous(cfg.design_variant)
    } else {
        crate::hetero::Mapping::homogeneous(cfg.design_variant)
    };
    let prompts: Vec<Vec<u32>> = batch.iter().map(|i| i.request.prompt.clone()).collect();
    let lat = lat.clone();
    let t_scheme = target.scheme;
    let sim_forward = move |bucket: usize, b: usize| {
        // Batched forward ~ b× the single-sequence FLOPs on the same PU
        // (no batching win on a saturated edge CPU), one dispatch boundary.
        let single = lat.forward_latency(&t_spec, t_scheme, mapping.target, bucket);
        let oh = match mapping.target {
            crate::hetero::PuAssignment::Cpu { .. } => lat.platform.cpu.dispatch_overhead_s,
            crate::hetero::PuAssignment::Gpu => lat.platform.gpu.dispatch_overhead_s,
        };
        (single - oh) * b as f64 + oh
    };
    // Batched artifacts exist only for the ref lowering (the Pallas path is
    // the batch-1 latency path; see aot.py) — batch decode always uses Ref.
    let outcomes = match batcher::batched_baseline(
        engine, target, crate::config::KernelPath::Ref, &prompts,
        cfg.max_new_tokens, &sim_forward,
    ) {
        Ok(o) => o,
        Err(_) => return,
    };
    for (item, o) in batch.into_iter().zip(outcomes) {
        let queue_s = item.enqueued.elapsed().as_secs_f64();
        metrics.record(RequestRecord {
            sim_s: o.sim_s,
            real_s: o.real_s,
            queue_s,
            tokens: o.tokens.len(),
            drafted: 0,
            accepted: 0,
        });
        let _ = item.respond.send(EngineResponse {
            id: item.request.id,
            completion: tokenizer.decode(&o.tokens),
            tokens: o.tokens,
            sim_s: o.sim_s,
            real_s: o.real_s,
            queue_s,
            alpha: f64::NAN,
            speculative: false,
            gamma: 0,
        });
    }
}
