//! Engine worker: owns one PJRT engine (the xla wrapper types are not
//! `Send`, so the engine lives and dies inside this thread) and runs a
//! tick-level continuous scheduler over the shared queue until shutdown.
//!
//! The worker keeps up to `cfg.max_inflight` live [`DecodeSession`]s and
//! advances all of them together through the fused batch executor
//! ([`super::fuser`]), one engine call per session per tick:
//!
//! 1. **reap** — at round boundaries, abort sessions whose request was
//!    cancelled or whose deadline expired: the scheduler slot frees for
//!    queued work and the response carries the tokens committed so far
//!    with a typed [`FinishReason`];
//! 2. **admit** — top the in-flight set up from the priority queue
//!    (blocking only when nothing is live), shedding items already
//!    cancelled or past deadline instead of decoding for nobody, and
//!    applying the request's [`GenOptions`] (per-request `max_new`,
//!    sampling mode/temperature/seed, stop conditions, speculation
//!    hints) to the new session;
//! 3. **consult** — re-run the routing [`Policy`] for every live session
//!    *at a round boundary*, clamped against the request's advisory
//!    [`SpecHints`], so γ and speculate-on/off are re-decided per round
//!    from the session's running α (the cost model in the hot loop);
//! 4. **tick** — every live session plans its next forward; the fuser
//!    groups compatible requests into shared batched dispatches — one
//!    dispatch group per routed PU — scatters the logits back, and
//!    schedules each dispatch on the worker's per-PU timelines
//!    ([`PuTimelines`]): with `cfg.hetero_overlap` on, draft forwards on
//!    one PU of a heterogeneous mapping overlap co-scheduled sessions'
//!    verify forwards on the other; off, a serialized single-clock
//!    timeline reproduces the pre-overlap behavior (`cfg.fuse = false`
//!    reverts to per-session stepping for A/B comparisons);
//! 5. **retire** — sessions whose round completed stream their newly
//!    committed tokens; finished sessions emit the final
//!    [`EngineResponse`] with its [`FinishReason`].
//!
//! **Deadline clock.** A request's `deadline_s` is charged real queueing
//! delay plus *simulated* decode seconds (the paper-comparable latency),
//! so deadline behavior is deterministic under the simulated platform.
//!
//! The lockstep batcher configuration (`max_batch > 1`, baseline decode)
//! is folded onto the same executor: those workers admit up to
//! `max_batch` sessions on the ref lowering (the only kernel with batched
//! artifacts), whose per-tick target forwards fuse into shared dispatches
//! — recovering batched baseline decode without the lockstep drain tail.
//! With `fuse: false` that configuration instead runs the quarantined
//! [`legacy_lockstep`](super::legacy_lockstep) loop, the true pre-fusion
//! A/B baseline.
//! Lifecycle state reaches that path at batch *boundaries*: dead items
//! are shed before the batch forms, requests whose options shape the
//! decode (per-request `max_new`, stops, sampling) are peeled off onto
//! the single-session path so typed options are never silently dropped,
//! and cancellation of a batched request takes effect between batches.

use crate::api::{FinishReason, GenOptions, SamplingMode};
use crate::config::{DecisionMode, DrafterMode, KernelPath, RunConfig};
use crate::decision::SpecHints;
use crate::dse::KvLoad;
use crate::hetero::{LatencyModel, Platform, PuId, PuTimelines, TimelineSnapshot};
use crate::kvcache::{KvManager, KvStats, SessionKv};
use crate::metrics::{KvRecord, Metrics, RequestRecord, RoundRecord};
use crate::models::ModelSpec;
use crate::runtime::Engine;
use crate::scenario::{DrafterRegistry, RequestClass};
use crate::spec::{AcceptRule, DecodeSession, DecoderSetup, StepOutcome};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use super::fuser::{self, TickEvent};
use super::legacy_lockstep;
use super::policy::Policy;
use super::queue::{QueueItem, RequestQueue};
use super::{CancelGuard, EngineResponse, TokenFrame};

/// One live request inside the worker's scheduler.
struct LiveSession {
    session: DecodeSession,
    respond: mpsc::Sender<EngineResponse>,
    token_tx: Option<mpsc::Sender<TokenFrame>>,
    id: u64,
    task: String,
    /// The drafter variant frozen into this session at admission (the
    /// class-selected one under `drafter: auto`, the configured default
    /// otherwise) — round consults and retire feedback are tagged with it.
    drafter: crate::models::VariantKey,
    /// The request's typed options (deadline/SLO accounting at retire).
    options: GenOptions,
    /// Advisory speculation hints extracted from the options, applied to
    /// every policy consult.
    hints: SpecHints,
    /// Cancellation flag (+ registry cleanup when this session drops).
    cancel: CancelGuard,
    /// Queue delay, measured at admission.
    queue_s: f64,
    /// Admission-time decision (reported in the final response).
    admitted_speculative: bool,
    admitted_gamma: usize,
    rounds: usize,
    /// Streaming hold-back (longest stop sequence − 1): trailing tokens
    /// that could still become part of a stop-sequence match are withheld
    /// from frames, so a cross-round match never truncates tokens a
    /// client has already seen — streamed frames always reassemble the
    /// final response exactly. 0 when the request has no stop sequences.
    stream_holdback: usize,
    /// Output tokens streamed so far (frames carry `tokens[streamed..]`
    /// up to the hold-back horizon).
    streamed: usize,
    /// Simulated timeline position at admission (per-PU timeline mode):
    /// per-request timeline latency = session finish − this.
    tl_admit_s: f64,
    /// Paged KV-cache reservation (`kv_cache: on` tick scheduler only):
    /// the session's shared-prefix path + private pages, released back to
    /// the worker's manager on retire and immediately on reap.
    kv: Option<SessionKv>,
}

impl LiveSession {
    /// Why this session must abort at the next round boundary (None =
    /// keep decoding). Cancellation outranks deadline expiry.
    fn abort_reason(&self) -> Option<FinishReason> {
        if self.cancel.cancelled() {
            return Some(FinishReason::Cancelled);
        }
        if let Some(d) = self.options.deadline_s {
            if self.queue_s + self.session.outcome().sim_s >= d {
                return Some(FinishReason::DeadlineExceeded);
            }
        }
        None
    }
}

/// Worker main loop (runs on its own thread).
#[allow(clippy::too_many_arguments)]
pub fn run_worker(
    wid: usize,
    cfg: RunConfig,
    platform: Platform,
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    policy: Arc<Policy>,
    shutdown: Arc<AtomicBool>,
    ready: mpsc::Sender<anyhow::Result<()>>,
) {
    // Build the engine inside the thread; report readiness (or the error).
    let engine = match Engine::load(&cfg.artifacts_dir) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(anyhow::anyhow!("worker {wid}: {e}")));
            return;
        }
    };
    let (drafter, target) = policy.variants();
    // Validate the configured variant keys against the manifest *before*
    // reporting ready: a config/manifest mismatch fails Coordinator::start
    // with a clear error instead of leaving callers waiting on a queue no
    // worker will ever serve.
    let (d_spec, t_spec) = match (
        engine.manifest.model_for(drafter).cloned(),
        engine.manifest.model_for(target).cloned(),
    ) {
        (Ok(d), Ok(t)) => (d, t),
        (d, t) => {
            let mut missing = Vec::new();
            if d.is_err() {
                missing.push(drafter.name());
            }
            if t.is_err() {
                missing.push(target.name());
            }
            let _ = ready.send(Err(anyhow::anyhow!(
                "worker {wid}: configured variant(s) [{}] not in the artifact \
                 manifest (check drafter_variant/target_variant in the run config)",
                missing.join(", ")
            )));
            return;
        }
    };
    // Auto drafter mode: register every manifest drafter variant with the
    // policy so per-class selection can switch among them. A manifest with
    // no drafter variants fails startup with a clear error, exactly like a
    // bad `drafter_variant` key.
    let mut warm_variants = vec![drafter, target];
    if policy.drafter_mode() == DrafterMode::Auto {
        match DrafterRegistry::from_manifest(&engine.manifest) {
            Ok(reg) => {
                for c in reg.candidates() {
                    if !warm_variants.contains(&c.key) {
                        warm_variants.push(c.key);
                    }
                }
                policy.set_drafter_registry(reg);
            }
            Err(e) => {
                let _ = ready.send(Err(anyhow::anyhow!("worker {wid}: {e}")));
                return;
            }
        }
    }
    let _ = ready.send(Ok(()));
    let tokenizer = match Tokenizer::from_manifest(&engine.manifest.tokenizer_spec) {
        Ok(t) => t,
        Err(_) => Tokenizer::builtin(),
    };
    // Batched-baseline configs decode on the ref lowering — the only
    // kernel path the AOT build lowers batch > 1 artifacts for (see
    // aot.py) — so their per-tick forwards can actually fuse.
    let serving_kernel = if cfg.max_batch > 1 && !cfg.speculative {
        KernelPath::Ref
    } else {
        cfg.kernel_path
    };
    // Warm the executable cache (batch-1 plus any batched artifacts) so
    // first requests don't pay compile time. Dual-kernel configs (the
    // lockstep baseline decodes batches on ref but serves lone requests
    // on the configured kernel) warm both.
    let buckets: Vec<usize> = engine.manifest.seq_buckets.clone();
    let _ = engine.warmup(&warm_variants, serving_kernel, &buckets);
    if !cfg.fuse && serving_kernel != cfg.kernel_path {
        let _ = engine.warmup(&warm_variants, cfg.kernel_path, &buckets);
    }

    // Paged KV cache (tick scheduler only): one manager per worker with
    // page pools sized from the platform memory model. `kv_cache: off`
    // (the default) never constructs one — admission, pricing and the
    // decision layer all stay bit-identical to the historical engine.
    let mut kv_mgr = if cfg.kv_cache.enabled() {
        Some(KvManager::new(
            &platform.memory,
            (&d_spec, drafter.scheme),
            (&t_spec, target.scheme),
        ))
    } else {
        None
    };
    let mut kv_reported = KvStats::default();

    let lat = LatencyModel::new(platform);

    // With fusion off, the batched-baseline configuration keeps the
    // legacy lockstep batcher — the true pre-fusion A/B baseline (whole
    // batches decode in lockstep, drained before the next admit).
    if !cfg.fuse && cfg.max_batch > 1 && !cfg.speculative {
        while !shutdown.load(Ordering::SeqCst) {
            let popped = queue.pop_batch(cfg.max_batch);
            if popped.is_empty() {
                break; // queue closed
            }
            // Shed items whose request died while queued before spending
            // a whole lockstep decode on them, and peel off requests
            // whose options shape the decode itself (max_new / stops /
            // sampling): the shared lockstep loop can't honor those, so
            // they run on the session path where every option applies —
            // strictly-validated options must never be silently dropped.
            let mut batch = Vec::with_capacity(popped.len());
            for item in popped {
                if let Some(reason) = shed_reason(&item) {
                    respond_shed(&metrics, item, reason);
                } else if has_decode_options(&item.request.options) {
                    let ls = admit(&cfg, &engine, &lat, &policy, &metrics, &tokenizer,
                                   &d_spec, &t_spec, item, drafter, target,
                                   cfg.kernel_path);
                    serve_single(&engine, &policy, &metrics, &tokenizer,
                                 &d_spec, &t_spec, ls);
                } else {
                    batch.push(item);
                }
            }
            if batch.is_empty() {
                continue;
            }
            if batch.len() == 1 {
                // Lone request under low traffic: the session path on the
                // configured kernel (batch-1 artifacts), with the normal
                // streaming/metrics behavior — exactly as before batching
                // kicks in.
                let item = batch.into_iter().next().unwrap();
                let ls = admit(&cfg, &engine, &lat, &policy, &metrics, &tokenizer,
                               &d_spec, &t_spec, item, drafter, target, cfg.kernel_path);
                serve_single(&engine, &policy, &metrics, &tokenizer,
                             &d_spec, &t_spec, ls);
            } else {
                serve_lockstep(&cfg, &engine, &lat, &tokenizer, &metrics, batch, target);
            }
        }
        return;
    }

    // The fused lockstep-batching configuration rides the tick scheduler:
    // admit enough baseline sessions that their per-tick target forwards
    // fill the compiled batch sizes.
    let max_inflight = cfg
        .max_inflight
        .max(if cfg.speculative { 1 } else { cfg.max_batch })
        .max(1);
    let mut live: Vec<LiveSession> = Vec::new();
    let mut queue_open = true;

    // Declare the deployment's KV load point so re-partition searches
    // treat page capacity as a feasibility filter: the full in-flight
    // set, each session budgeted at the largest compiled context.
    if kv_mgr.is_some() {
        policy.set_kv_load(KvLoad {
            inflight: max_inflight,
            budget_tokens: buckets.last().copied().unwrap_or(cfg.max_new_tokens).max(1),
        });
    }

    // Per-PU timelines for the tick scheduler: overlapped when the knob is
    // on (dispatches routed to different PUs of the mapping proceed
    // concurrently), single-clock serialized otherwise — identical
    // dispatches and per-session charges either way, so `hetero_overlap:
    // false` reproduces the pre-overlap behavior bit-for-bit while still
    // reporting the serialized makespan for A/B comparison.
    let mut timelines = if cfg.hetero_overlap {
        PuTimelines::new()
    } else {
        PuTimelines::serialized()
    };
    let mut tl_reported = TimelineSnapshot::default();
    // Dispatch observations are only worth collecting when a calibrated
    // model is there to consume them.
    let calibrating = policy.decision_mode() == DecisionMode::Calibrated;

    loop {
        // ---- reap: abort dead sessions at round boundaries ------------
        // Cancelled / deadline-expired sessions leave *before* admission
        // tops the set up, so their slots go to queued work this very
        // iteration — the "cancel frees the slot" contract.
        let mut i = 0;
        while i < live.len() {
            let abort = if live[i].session.mid_round() {
                None // only ever abort between rounds
            } else {
                live[i].abort_reason()
            };
            match abort {
                Some(reason) => {
                    let mut ls = live.remove(i);
                    // Reaped pages come back *now* — the freed slot is
                    // only useful if the next admission can also reserve
                    // KV — and the reap walk drops the session's
                    // now-unreferenced prefix nodes too.
                    if let (Some(mgr), Some(kv)) = (kv_mgr.as_mut(), ls.kv.take()) {
                        mgr.release(kv, true);
                    }
                    let tl_s = if cfg.fuse {
                        Some((ls.session.ready_s() - ls.tl_admit_s).max(0.0))
                    } else {
                        None
                    };
                    abort_session(&tokenizer, &metrics, &policy, ls, tl_s, reason);
                }
                None => i += 1,
            }
        }

        // ---- admit: top up the in-flight set -------------------------
        // On shutdown, stop admitting but finish the (bounded) in-flight
        // set — "complete the current requests" semantics.
        while queue_open && !shutdown.load(Ordering::SeqCst) && live.len() < max_inflight {
            let item = if live.is_empty() {
                // Nothing to step: block until work arrives or close.
                match queue.pop() {
                    Some(i) => i,
                    None => {
                        queue_open = false;
                        break;
                    }
                }
            } else {
                match queue.try_pop() {
                    Some(i) => i,
                    None => break,
                }
            };
            // Deadline-based admission shedding (and cancelled-in-queue):
            // answer immediately, never occupy a slot.
            if let Some(reason) = shed_reason(&item) {
                respond_shed(&metrics, item, reason);
                continue;
            }
            // Memory-aware admission: reserve the session's whole KV
            // budget (prompt + generation window) before it occupies a
            // scheduler slot. The prompt is snapshotted first — admit()
            // consumes the queue item.
            let kv_prompt = if kv_mgr.is_some() {
                item.request.prompt.clone()
            } else {
                Vec::new()
            };
            let kv_max_new = item
                .request
                .options
                .max_new
                .map(|m| m.clamp(1, cfg.max_new_limit))
                .unwrap_or(cfg.max_new_tokens);
            let mut ls = admit(&cfg, &engine, &lat, &policy, &metrics, &tokenizer,
                               &d_spec, &t_spec, item, drafter, target, serving_kernel);
            if let Some(mgr) = kv_mgr.as_mut() {
                let budget = kv_prompt.len() + kv_max_new;
                match mgr.admit(&kv_prompt, ls.session.mapping(), budget) {
                    Some(kv) => {
                        // Prompt tokens the prefix cache already holds:
                        // the session's forwards price them as resident.
                        ls.session.set_kv_prefix(kv.shared_tokens());
                        ls.kv = Some(kv);
                    }
                    None => {
                        // Pools exhausted even after eviction: typed
                        // overload rejection instead of thrashing.
                        shed_overloaded(&metrics, ls);
                        continue;
                    }
                }
            }
            // A session admitted mid-stream starts at the worker's
            // current simulated "now" (the earliest frontier among PUs
            // the workload actually uses): its first dispatch cannot
            // reach back before that, and its timeline latency is
            // measured from here.
            ls.tl_admit_s = timelines.now();
            ls.session.set_ready_s(ls.tl_admit_s);
            live.push(ls);
        }
        if live.is_empty() {
            if !queue_open || shutdown.load(Ordering::SeqCst) {
                break;
            }
            continue;
        }

        // ---- consult: round-level policy at round boundaries ----------
        for ls in live.iter_mut() {
            if ls.session.mid_round() || ls.session.is_done() {
                continue;
            }
            // Priced at the session's admission-frozen mapping *and*
            // drafter variant: an online re-partition (or a per-class
            // drafter switch) must not re-score in-flight sessions
            // against routes they are not running on. Clamped against
            // the request's advisory hints every round.
            let dec = policy.route_round_with_drafter(
                &ls.task, ls.drafter, &d_spec, &t_spec, ls.session.mapping(),
                ls.session.seq_len(), ls.session.n_drafted(), ls.session.alpha_so_far(),
                ls.hints,
            );
            if dec.used_prior {
                metrics.record_prior_decision();
            }
            ls.session.set_speculative(dec.speculative);
            if dec.speculative {
                // Artifact-aware: monolithic fused graphs only exist for
                // the γs the AOT build lowered, so the serving path clamps.
                ls.session.set_gamma_checked(&engine, dec.gamma);
            }
            // Chain vs tree is re-decided at every round boundary too; the
            // session normalizes (None / 1xD → chain, bit-identical).
            ls.session.set_tree(if dec.speculative { dec.tree } else { None });
        }

        // ---- tick: advance every session one engine call --------------
        let inflight_now = live.len();
        let events = if cfg.fuse {
            let mut refs: Vec<&mut DecodeSession> =
                live.iter_mut().map(|ls| &mut ls.session).collect();
            let (events, stats) =
                fuser::tick(&engine, &lat, &mut refs, Some(&mut timelines), calibrating);
            metrics.record_dispatches(
                stats.dispatches as u64,
                stats.fused_dispatches as u64,
                stats.lanes_real as u64,
                stats.lanes_executed as u64,
            );
            // Close the predict → measure → correct loop: the tick's
            // observed dispatch durations feed the calibrated cost model
            // (consumes nothing under `decision: "analytic"`).
            if !stats.observations.is_empty() {
                let fed = policy.observe_dispatches(&stats.observations);
                metrics.record_calibration(fed as u64);
            }
            // Push this tick's timeline growth (all deltas, makespan
            // included, sum across workers' independent timelines).
            let snap = timelines.snapshot();
            metrics.record_timeline(&snap, &tl_reported);
            tl_reported = snap;
            events
        } else {
            // Unfused A/B path: one full round per session per tick, each
            // engine call its own dispatch.
            let mut events = Vec::with_capacity(live.len());
            let mut calls = 0u64;
            for ls in live.iter_mut() {
                let before = engine.n_forward_calls.get();
                events.push(match ls.session.step(&engine) {
                    Ok(out) => TickEvent::Round(out),
                    Err(_) => TickEvent::Failed,
                });
                calls += engine.n_forward_calls.get() - before;
            }
            metrics.record_dispatches(calls, 0, calls, calls);
            events
        };

        // ---- retire: stream, record, answer ---------------------------
        // Walk backwards so removals keep earlier indices valid.
        debug_assert_eq!(events.len(), live.len());
        let mut idx = live.len();
        for ev in events.into_iter().rev() {
            idx -= 1;
            match ev {
                TickEvent::Pending => {}
                TickEvent::Failed => {
                    // Dropping the sender(s) signals the error to the caller.
                    let mut ls = live.remove(idx);
                    if let (Some(mgr), Some(kv)) = (kv_mgr.as_mut(), ls.kv.take()) {
                        mgr.release(kv, false);
                    }
                }
                TickEvent::Round(out) => {
                    let done =
                        finish_round(&metrics, &mut live[idx], out, inflight_now);
                    if done {
                        let mut ls = live.remove(idx);
                        // Retire release keeps the session's prefix nodes
                        // cached (zero-ref retention) for the next
                        // request sharing the prompt.
                        if let (Some(mgr), Some(kv)) = (kv_mgr.as_mut(), ls.kv.take()) {
                            mgr.release(kv, false);
                        }
                        let tl_s = if cfg.fuse {
                            Some((ls.session.ready_s() - ls.tl_admit_s).max(0.0))
                        } else {
                            None
                        };
                        retire(&tokenizer, &metrics, &policy, ls, tl_s, None);
                    }
                }
            }
        }

        // ---- sync: fold this worker's KV accounting into the report ----
        if let Some(mgr) = kv_mgr.as_ref() {
            sync_kv(&metrics, wid, mgr, &mut kv_reported);
        }
    }
    if let Some(mgr) = kv_mgr.as_ref() {
        sync_kv(&metrics, wid, mgr, &mut kv_reported);
    }
}

/// Push one worker's [`KvManager`] counter growth since the last sync —
/// plus its current per-PU page gauges — into the shared metrics sink.
fn sync_kv(metrics: &Metrics, wid: usize, mgr: &KvManager, reported: &mut KvStats) {
    let s = mgr.stats();
    let occ = |pu: PuId| {
        let (used, peak, cap) = mgr.occupancy(pu);
        [used as u64, peak as u64, cap as u64]
    };
    let rec = KvRecord {
        lookups: s.lookups - reported.lookups,
        prefix_probe_tokens: s.prefix_probe_tokens - reported.prefix_probe_tokens,
        prefix_hit_tokens: s.prefix_hit_tokens - reported.prefix_hit_tokens,
        prefill_tokens_saved: s.prefill_tokens_saved - reported.prefill_tokens_saved,
        memory_shed: s.memory_shed - reported.memory_shed,
        reap_reclaimed_pages: s.reap_reclaimed_pages - reported.reap_reclaimed_pages,
        occupancy: [occ(PuId::Cpu), occ(PuId::Gpu)],
    };
    *reported = s;
    metrics.record_kv(wid, rec);
}

/// Answer a session the paged KV cache could not reserve pages for even
/// after eviction: typed overload rejection — no decode ever ran, so only
/// the lifecycle counters move (mirrors [`respond_shed`] for items that
/// made it past routing).
fn shed_overloaded(metrics: &Metrics, ls: LiveSession) {
    metrics.record_rejected();
    metrics.record_finish(FinishReason::Rejected);
    metrics.record_slo(ls.options.slo);
    if ls.options.deadline_s.is_some() {
        // A rejected deadline-carrying request can never meet it.
        metrics.record_deadline(true);
    }
    if let Some(tx) = &ls.token_tx {
        let _ = tx.send(TokenFrame {
            id: ls.id,
            round: 1,
            tokens: Vec::new(),
            drafted: 0,
            accepted: 0,
            done: true,
        });
    }
    let _ = ls
        .respond
        .send(EngineResponse::shed(ls.id, ls.queue_s, FinishReason::Rejected));
}

/// Whether a request's options change the decode itself (vs only its
/// scheduling), i.e. whether the shared lockstep loop — which decodes
/// every lane under the server defaults — would silently drop them.
fn has_decode_options(o: &GenOptions) -> bool {
    o.max_new.is_some()
        || o.sampling != SamplingMode::Greedy
        || !o.stop_sequences.is_empty()
        || !o.stop_tokens.is_empty()
}

/// Why a still-queued item must be shed instead of admitted.
fn shed_reason(item: &QueueItem) -> Option<FinishReason> {
    if item.cancelled() {
        Some(FinishReason::Cancelled)
    } else if item.deadline_expired() {
        Some(FinishReason::DeadlineExceeded)
    } else {
        None
    }
}

/// Answer a request that never reached a session (cancelled in the queue,
/// or deadline-expired before admission): typed response, no tokens, no
/// latency-population pollution — only the lifecycle counters move.
fn respond_shed(metrics: &Metrics, item: QueueItem, reason: FinishReason) {
    let queue_s = item.enqueued.elapsed().as_secs_f64();
    metrics.record_finish(reason);
    metrics.record_slo(item.request.options.slo);
    if item.request.options.deadline_s.is_some() {
        // A cancelled item whose deadline had also already expired still
        // missed its deadline — don't let the cancel mask the miss.
        metrics.record_deadline(
            reason == FinishReason::DeadlineExceeded || item.deadline_expired(),
        );
    }
    if let Some(tx) = &item.token_tx {
        let _ = tx.send(TokenFrame {
            id: item.request.id,
            round: 1,
            tokens: Vec::new(),
            drafted: 0,
            accepted: 0,
            done: true,
        });
    }
    let _ = item
        .respond
        .send(EngineResponse::shed(item.request.id, queue_s, reason));
}

/// Account one completed round: per-round metrics and streamed tokens.
/// Returns whether the session finished.
fn finish_round(
    metrics: &Metrics,
    ls: &mut LiveSession,
    step: StepOutcome,
    inflight_now: usize,
) -> bool {
    ls.rounds += 1;
    // Bookkeeping steps that only discovered completion (born-finished
    // cap==0 sessions, bucket-edge termination) ran no engine work and
    // would dilute the per-round metrics.
    let worked = step.drafted > 0 || !step.committed.is_empty() || step.sim_s > 0.0;
    if worked {
        metrics.record_round(RoundRecord {
            drafted: step.drafted,
            accepted: step.accepted,
            sim_s: step.sim_s,
            real_s: step.real_s,
            inflight: inflight_now,
            tree_lanes_executed: step.tree_lanes_executed,
            tree_lanes_real: step.tree_lanes_real,
        });
    }
    if let Some(tx) = &ls.token_tx {
        // Stream from the session's authoritative output, withholding
        // the hold-back tail while stop sequences are still in play (see
        // `stream_holdback`); the final frame flushes everything that
        // survived truncation. Without stop sequences this is exactly
        // the per-round committed delta.
        let out = &ls.session.outcome().tokens;
        let visible = if step.done {
            out.len()
        } else {
            out.len().saturating_sub(ls.stream_holdback)
        };
        let from = ls.streamed.min(visible);
        let tokens = out[from..visible].to_vec();
        if !tokens.is_empty() || step.done {
            ls.streamed = visible;
            let _ = tx.send(TokenFrame {
                id: ls.id,
                round: ls.rounds,
                tokens,
                drafted: step.drafted,
                accepted: step.accepted,
                done: step.done,
            });
        }
    }
    step.done
}

/// Route one queue item and wrap it into a live session, applying the
/// request's [`GenOptions`]: per-request `max_new` (clamped to the
/// server's `max_new_limit`), sampling mode (stochastic gets the
/// request's seed + temperature), stop token ids and stop sequences
/// (encoded with the serving tokenizer — a sequence whose characters the
/// vocabulary cannot express can never be generated, so it is dropped),
/// and advisory speculation hints clamped over the admission decision.
/// The mapping the decision carries is frozen into the session's setup
/// here — an online re-partition switch therefore only affects *future*
/// admissions.
#[allow(clippy::too_many_arguments)]
fn admit(
    cfg: &RunConfig,
    engine: &Engine,
    lat: &LatencyModel,
    policy: &Policy,
    metrics: &Metrics,
    tokenizer: &Tokenizer,
    d_spec: &ModelSpec,
    t_spec: &ModelSpec,
    item: QueueItem,
    drafter: crate::models::VariantKey,
    target: crate::models::VariantKey,
    kernel: KernelPath,
) -> LiveSession {
    let queue_s = item.enqueued.elapsed().as_secs_f64();
    let req = item.request;
    let options = req.options.clone();
    let hints = SpecHints::from_options(&options);
    // Per-class drafter selection (`drafter: auto`): admit onto the task
    // class's chosen variant. Fixed mode resolves to the configured
    // default, making this exactly the historical `route_with` admission.
    let drafter = if policy.drafter_mode() == DrafterMode::Auto {
        policy.drafter_for(&req.task)
    } else {
        drafter
    };
    let decision =
        policy.route_with_drafter(&req.task, drafter, d_spec, t_spec, req.prompt.len(), hints);
    if decision.used_prior {
        metrics.record_prior_decision();
    }
    let max_new = options
        .max_new
        .map(|m| m.clamp(1, cfg.max_new_limit))
        .unwrap_or(cfg.max_new_tokens);
    let rule = match options.sampling {
        SamplingMode::Greedy => AcceptRule::Greedy,
        SamplingMode::Stochastic { .. } => AcceptRule::Stochastic,
    };
    let setup = DecoderSetup {
        drafter,
        target,
        kernel,
        mapping: decision.mapping,
        gamma: decision.gamma.max(1),
        rule,
        exec: cfg.exec_mode,
        max_new,
    };
    let mut session =
        DecodeSession::new(engine, lat.clone(), setup, decision.speculative, &req.prompt);
    // Admission decision's tree shape (None under `tree: off` — chain,
    // bit-identical); round-boundary consults keep it current after this.
    session.set_tree(decision.tree);
    if let SamplingMode::Stochastic { temperature, seed } = options.sampling {
        session = session.with_rng(Rng::new(seed));
        session.set_temperature(temperature as f32);
    }
    if !options.stop_tokens.is_empty() {
        session.set_stop_tokens(options.stop_tokens.clone());
    }
    let mut stream_holdback = 0;
    if !options.stop_sequences.is_empty() {
        let encoded: Vec<Vec<u32>> = options
            .stop_sequences
            .iter()
            .filter_map(|s| tokenizer.encode(s, false).ok())
            .collect();
        // A match can reach back at most (longest stop − 1) tokens past
        // the one that completes it; withholding that many from the
        // stream keeps frames truncation-exact.
        stream_holdback = encoded.iter().map(Vec::len).max().unwrap_or(1).saturating_sub(1);
        session.set_stop_sequences(encoded);
    }
    LiveSession {
        session,
        respond: item.respond,
        token_tx: item.token_tx,
        id: req.id,
        task: req.task,
        drafter,
        options,
        hints,
        cancel: item.cancel,
        queue_s,
        admitted_speculative: decision.speculative,
        admitted_gamma: decision.gamma,
        rounds: 0,
        stream_holdback,
        streamed: 0,
        tl_admit_s: 0.0,
        kv: None,
    }
}

/// Drive one admitted session to completion — the scheduler path
/// specialized to a single in-flight session (the lockstep configuration
/// uses it for lone requests, so low traffic keeps the normal
/// kernel/streaming/metrics behavior). This legacy A/B path steps the
/// session directly and does **not** feed the calibration loop — only
/// the fused tick executor reports dispatch observations. Cancellation
/// and deadline expiry abort at round boundaries exactly like the tick
/// scheduler.
fn serve_single(
    engine: &Engine,
    policy: &Policy,
    metrics: &Metrics,
    tokenizer: &Tokenizer,
    d_spec: &ModelSpec,
    t_spec: &ModelSpec,
    mut ls: LiveSession,
) {
    loop {
        if let Some(reason) = ls.abort_reason() {
            abort_session(tokenizer, metrics, policy, ls, None, reason);
            return;
        }
        // Round-level policy, as in the tick scheduler.
        let dec = policy.route_round_with_drafter(
            &ls.task, ls.drafter, d_spec, t_spec, ls.session.mapping(),
            ls.session.seq_len(), ls.session.n_drafted(), ls.session.alpha_so_far(),
            ls.hints,
        );
        if dec.used_prior {
            metrics.record_prior_decision();
        }
        ls.session.set_speculative(dec.speculative);
        if dec.speculative {
            ls.session.set_gamma_checked(engine, dec.gamma);
        }
        ls.session.set_tree(if dec.speculative { dec.tree } else { None });
        match ls.session.step(engine) {
            Err(_) => return, // dropped senders signal the error
            Ok(out) => {
                if finish_round(metrics, &mut ls, out, 1) {
                    retire(tokenizer, metrics, policy, ls, None, None);
                    return;
                }
            }
        }
    }
}

/// Legacy lockstep batched-baseline decode (`fuse: false` A/B path):
/// whole batches advance one token per shared `forward_batch` call and
/// drain together before the next batch is admitted.
fn serve_lockstep(
    cfg: &RunConfig,
    engine: &Engine,
    lat: &LatencyModel,
    tokenizer: &Tokenizer,
    metrics: &Metrics,
    batch: Vec<QueueItem>,
    target: crate::models::VariantKey,
) {
    let t_spec = match engine.manifest.model_for(target) {
        Ok(s) => s.clone(),
        Err(_) => return,
    };
    let mapping = if cfg.heterogeneous {
        crate::hetero::Mapping::heterogeneous(cfg.design_variant)
    } else {
        crate::hetero::Mapping::homogeneous(cfg.design_variant)
    };
    let prompts: Vec<Vec<u32>> = batch.iter().map(|i| i.request.prompt.clone()).collect();
    // Queue delay snapshots *before* the shared decode runs: the serving
    // clock (and the deadline metric) charges real queueing + simulated
    // decode, never real decode wall-time.
    let queued_s: Vec<f64> = batch
        .iter()
        .map(|i| i.enqueued.elapsed().as_secs_f64())
        .collect();
    let lat = lat.clone();
    let t_scheme = target.scheme;
    // Simulated cost of one batched forward at the *executed* lane count
    // (the batcher's amortization rule splits it over the real requests).
    let sim_forward = move |bucket: usize, exec_b: usize| {
        lat.batched_forward_latency(&t_spec, t_scheme, mapping.target, bucket, exec_b)
    };
    // Batched artifacts exist only for the ref lowering (see aot.py).
    let outcomes = match legacy_lockstep::batched_baseline(
        engine, target, KernelPath::Ref, &prompts, cfg.max_new_tokens, &sim_forward,
    ) {
        Ok(o) => o,
        Err(_) => return,
    };
    for ((item, o), queue_s) in batch.into_iter().zip(outcomes).zip(queued_s) {
        let finish = if o.eos { FinishReason::Stop } else { FinishReason::Length };
        metrics.record(RequestRecord {
            sim_s: o.sim_s,
            real_s: o.real_s,
            queue_s,
            tokens: o.tokens.len(),
            drafted: 0,
            accepted: 0,
        });
        metrics.record_finish(finish);
        metrics.record_slo(item.request.options.slo);
        if let Some(d) = item.request.options.deadline_s {
            metrics.record_deadline(queue_s + o.sim_s >= d);
        }
        // Lockstep batching has no per-round commits; streaming callers
        // still get their terminating done-frame with the full output.
        if let Some(tx) = &item.token_tx {
            let _ = tx.send(TokenFrame {
                id: item.request.id,
                round: 1,
                tokens: o.tokens.clone(),
                drafted: 0,
                accepted: 0,
                done: true,
            });
        }
        let _ = item.respond.send(EngineResponse {
            id: item.request.id,
            completion: tokenizer.decode(&o.tokens),
            tokens: o.tokens,
            sim_s: o.sim_s,
            real_s: o.real_s,
            queue_s,
            alpha: f64::NAN,
            speculative: false,
            gamma: 0,
            // The request's lockstep rounds: one per shared decode step
            // it was live for (the seed code reported a constant 0 here).
            rounds: o.target_calls,
            finish,
        });
    }
}

/// Abort a live session at a round boundary (cancellation or deadline
/// expiry): emit a terminating frame for streaming consumers — flushing
/// any tokens the stop-sequence hold-back had withheld, so frames still
/// reassemble the final partial output — then retire with the tokens
/// committed so far under the typed reason.
fn abort_session(
    tokenizer: &Tokenizer,
    metrics: &Metrics,
    policy: &Policy,
    ls: LiveSession,
    tl_latency: Option<f64>,
    reason: FinishReason,
) {
    if let Some(tx) = &ls.token_tx {
        let out = &ls.session.outcome().tokens;
        let tokens = out[ls.streamed.min(out.len())..].to_vec();
        let _ = tx.send(TokenFrame {
            id: ls.id,
            round: ls.rounds + 1,
            tokens,
            drafted: 0,
            accepted: 0,
            done: true,
        });
    }
    retire(tokenizer, metrics, policy, ls, tl_latency, Some(reason));
}

/// Account for and answer one finished session. `tl_latency` is the
/// request's end-to-end latency on the per-PU timelines (admission →
/// last dispatch end), when the worker tracked one. `finish_override`
/// stamps round-boundary aborts (cancel/deadline); otherwise the
/// session's own finish reason stands.
fn retire(
    tokenizer: &Tokenizer,
    metrics: &Metrics,
    policy: &Policy,
    ls: LiveSession,
    tl_latency: Option<f64>,
    finish_override: Option<FinishReason>,
) {
    let outcome = ls.session.into_outcome();
    let finish = finish_override.unwrap_or(outcome.finish);
    // Tagged with the session's drafter so auto mode accrues per-class,
    // per-variant evidence (fixed mode: exactly `observe_alpha`).
    policy.observe_alpha_tagged(&ls.task, ls.drafter, outcome.alpha());
    metrics.record_class(RequestClass::for_task(&ls.task), outcome.alpha(), &ls.drafter.name());
    if let Some(t) = tl_latency {
        metrics.record_timeline_latency(t);
    }
    metrics.record(RequestRecord {
        sim_s: outcome.sim_s,
        real_s: outcome.real_s,
        queue_s: ls.queue_s,
        tokens: outcome.tokens.len(),
        drafted: outcome.n_drafted,
        accepted: outcome.n_accepted,
    });
    metrics.record_finish(finish);
    metrics.record_slo(ls.options.slo);
    if let Some(d) = ls.options.deadline_s {
        // A request that completed but blew its budget still missed.
        metrics.record_deadline(
            finish == FinishReason::DeadlineExceeded || ls.queue_s + outcome.sim_s >= d,
        );
    }
    let completion = tokenizer.decode(&outcome.tokens);
    let alpha = outcome.alpha();
    let _ = ls.respond.send(EngineResponse {
        id: ls.id,
        completion,
        tokens: outcome.tokens,
        sim_s: outcome.sim_s,
        real_s: outcome.real_s,
        queue_s: ls.queue_s,
        alpha,
        speculative: ls.admitted_speculative,
        gamma: ls.admitted_gamma,
        rounds: ls.rounds,
        finish,
    });
}
