//! Cross-session fused batch executor with per-PU timeline scheduling.
//!
//! One scheduler *tick* advances every live [`DecodeSession`] by exactly
//! one engine call: each session [`plan`](DecodeSession::plan)s the
//! forward it needs, the fuser groups the pending [`EngineRequest`]s by
//! fusion key `(variant, kernel, bucket, pu)`, dispatches each group as
//! one `Engine::forward_batch` call — padding partial groups up to the
//! manifest's compiled batch sizes, falling back to batch=1 dispatches
//! when no batched artifact exists for the key — and scatters the logits
//! rows back through [`apply`](DecodeSession::apply).
//!
//! Because every speculative session spends most of its life issuing
//! same-shape drafter (then target) forwards, co-scheduled sessions fuse
//! naturally: γ co-resident requests in their draft phase become one
//! γ-lane drafter dispatch instead of γ separate dispatches, amortizing
//! the per-call runtime-API boundary the cost model charges γ+1 times per
//! round. Monolithic spec-steps are never cross-fused (the fused graph is
//! already one dispatch per round).
//!
//! **Per-PU timelines.** When the caller supplies a
//! [`PuTimelines`], every dispatch is additionally *scheduled* on the
//! timeline of the PU its [`EngineRequest::route`] names (resolved from
//! the policy-chosen mapping at plan time): the dispatch begins at
//! `max(pu_ready, inputs_ready)`, where `inputs_ready` is the latest
//! [`DecodeSession::ready_s`] among the sessions sharing it. Groups
//! routed to *different* PUs of a heterogeneous mapping therefore proceed
//! concurrently within the tick — one session's draft forwards on the GPU
//! overlap co-scheduled sessions' verify forwards on the CPU cluster —
//! while a serialized timeline ([`PuTimelines::serialized`], the
//! `hetero_overlap: false` A/B baseline) queues every dispatch behind
//! every other. Group dispatch order within a tick is made deterministic
//! by sorting on the fusion key, so simulated makespans are reproducible.
//! Per-session `sim_s` charges are identical with and without timelines;
//! the timelines add makespan/busy/overlap observables, they do not
//! change what each session pays.
//!
//! **Clock honesty.** A fused dispatch of `m` real sessions executed as
//! `exec_b ≥ m` lanes is charged
//! [`LatencyModel::batched_forward_latency`]`(…, exec_b)` — `exec_b ×` the
//! single-lane compute plus **one** dispatch boundary — split evenly
//! across the `m` real sessions (padding lanes are overhead the sharers
//! absorb; no simulated time vanishes). The PU timeline is occupied for
//! the *full* batched duration. Real wall-clock is split the same way.
//! Singleton fallbacks charge the ordinary single-call latency, so
//! `fuse = false` and batch-1-only kernels reproduce the pre-fusion clock
//! exactly.
//!
//! **Calibration feed.** When the caller opts in (`collect_obs` — the
//! worker passes whether the decision layer runs the calibrated model),
//! every executed forward dispatch — fused or singleton — is reported in
//! [`TickStats::observations`] (variant, kernel, bucket, PU, executed
//! lanes, duration), which the worker forwards to the decision layer so
//! the calibrated cost model ([`crate::decision::CalibratedModel`]) can
//! refit its latency coefficients from what actually ran. Analytic-mode
//! serving collects nothing.
//!
//! Note the deliberate trade-off in partial fills: padding a 2-session
//! group to a compiled batch of 4 buys one saved dispatch boundary for
//! two lanes of extra simulated compute, which under the calibrated edge
//! model can exceed the saving. The executor fuses unconditionally —
//! dispatch-count reduction is the architectural goal (and what real
//! batched backends amortize far better than the b× pessimistic sim) —
//! and reports the padding honestly via the batch-fill metric; letting
//! the routing policy cost-gate fusion per group is future work.

use std::collections::HashMap;

use crate::decision::DispatchObs;
use crate::hetero::{LatencyModel, PuId, PuTimelines};
use crate::runtime::Engine;
use crate::spec::{
    DecodeSession, EngineReply, EngineRequest, ForwardReply, FuseKey, RequestKind,
    SessionPlan, StepOutcome, StepProgress,
};

/// What one tick did to one session (indexed like the `sessions` slice).
#[derive(Debug)]
pub enum TickEvent {
    /// Mid-round: the session has more engine work next tick.
    Pending,
    /// The session completed a round (or a bookkeeping no-work step).
    Round(StepOutcome),
    /// Planning, dispatch or apply failed; the caller should drop the
    /// session (its response channels signal the error when dropped).
    Failed,
}

/// Dispatch accounting for one tick.
#[derive(Debug, Clone, Default)]
pub struct TickStats {
    /// Engine calls issued (fused, singleton and mono alike).
    pub dispatches: usize,
    /// Dispatches that carried more than one session.
    pub fused_dispatches: usize,
    /// Session lanes across all dispatches.
    pub lanes_real: usize,
    /// Executed lanes across all dispatches (padding included).
    pub lanes_executed: usize,
    /// One record per executed forward dispatch — the calibration feed
    /// ([`crate::decision::CalibratedModel`]): what ran where, over how
    /// many lanes, and the observed duration. Collected only when the
    /// caller asks ([`tick`]'s `collect_obs` — the worker passes the
    /// decision mode, so analytic serving pays nothing). Mono spec-steps
    /// are excluded (their fused graph has no single-forward shape to
    /// fit).
    pub observations: Vec<DispatchObs>,
}

/// Compiled batch sizes for (variant, kernel, bucket), ascending (the
/// manifest is the single source of truth — same query warmup uses).
/// Always non-empty: `[1]` when nothing is lowered, so the subsequent
/// batch-1 dispatch surfaces the real error.
fn compiled_batches(engine: &Engine, key: FuseKey) -> Vec<usize> {
    let (variant, kernel, bucket, _pu) = key;
    let mut sizes = engine.manifest.batch_sizes_for(variant, kernel, bucket);
    if sizes.is_empty() {
        sizes.push(1);
    }
    sizes
}

/// Split `k` pending requests into dispatch chunks `(m, exec_b)`: `m` real
/// lanes executed as the smallest compiled batch `exec_b ≥ m` (the largest
/// compiled size when the group overflows it).
fn plan_chunks(k: usize, sizes: &[usize]) -> Vec<(usize, usize)> {
    debug_assert!(!sizes.is_empty());
    let largest = *sizes.last().unwrap();
    let mut chunks = Vec::new();
    let mut remaining = k;
    while remaining > 0 {
        let exec_b = sizes
            .iter()
            .copied()
            .find(|&s| s >= remaining)
            .unwrap_or(largest);
        let m = remaining.min(exec_b);
        chunks.push((m, exec_b));
        remaining -= m;
    }
    chunks
}

/// Advance every session one engine call: plan, fuse, dispatch, scatter —
/// and, when `timelines` is supplied, schedule each dispatch on its routed
/// PU's timeline (overlapped or serialized per the timelines' mode).
/// With `collect_obs` set, every forward dispatch is additionally
/// recorded in [`TickStats::observations`] for the calibration feed.
///
/// Returns one [`TickEvent`] per session (same order as `sessions`) plus
/// the tick's dispatch accounting. Sessions that are already done come
/// back as `Round` with a `done` outcome, mirroring `step()` semantics.
pub fn tick(
    engine: &Engine,
    lat: &LatencyModel,
    sessions: &mut [&mut DecodeSession],
    mut timelines: Option<&mut PuTimelines>,
    collect_obs: bool,
) -> (Vec<TickEvent>, TickStats) {
    let n = sessions.len();
    let mut events: Vec<Option<TickEvent>> = Vec::with_capacity(n);
    events.resize_with(n, || None);
    let mut stats = TickStats::default();

    // ---- phase 1: collect every session's pending request ------------
    let mut groups: HashMap<FuseKey, Vec<(usize, EngineRequest)>> = HashMap::new();
    let mut singles: Vec<(usize, EngineRequest)> = Vec::new();
    for (i, s) in sessions.iter_mut().enumerate() {
        match s.plan(engine) {
            Err(_) => events[i] = Some(TickEvent::Failed),
            Ok(SessionPlan::Done(out)) => events[i] = Some(TickEvent::Round(out)),
            Ok(SessionPlan::Need(req)) => match req.fuse_key() {
                Some(key) => groups.entry(key).or_default().push((i, req)),
                None => singles.push((i, req)),
            },
        }
    }

    // ---- phase 2: mono spec-steps run as singleton dispatches ---------
    for (i, req) in &singles {
        events[*i] = Some(run_single(
            engine, &mut *sessions[*i], req, &mut stats, &mut timelines, collect_obs,
        ));
    }

    // ---- phase 3: fused groups, one dispatch sequence per PU ----------
    // Sort groups on the fusion key so dispatch order — and with it the
    // per-PU timeline placement — is deterministic run-to-run.
    let mut groups: Vec<(FuseKey, Vec<(usize, EngineRequest)>)> = groups.into_iter().collect();
    groups.sort_by_key(|(key, _)| *key);
    for (key, group) in groups {
        let (variant, kernel, bucket, pu) = key;
        let sizes = compiled_batches(engine, key);
        let batched_possible = *sizes.last().unwrap() > 1;
        let spec = match engine.manifest.model_for(variant) {
            Ok(s) => s.clone(),
            Err(_) => {
                for (i, req) in &group {
                    events[*i] = Some(run_single(
                        engine, &mut *sessions[*i], req, &mut stats, &mut timelines,
                        collect_obs,
                    ));
                }
                continue;
            }
        };
        let mut offset = 0usize;
        for (m, exec_b) in plan_chunks(group.len(), &sizes) {
            let chunk = &group[offset..offset + m];
            offset += m;
            if exec_b == 1 || !batched_possible {
                // No batched artifact for this key (e.g. the Pallas
                // lowering is batch-1 only): unbatched fallback.
                for (i, req) in chunk {
                    events[*i] = Some(run_single(
                        engine, &mut *sessions[*i], req, &mut stats, &mut timelines,
                        collect_obs,
                    ));
                }
                continue;
            }
            // Pad partial chunks by replicating the first lane; its rows
            // beyond `m` are never scattered.
            let mut views: Vec<&[u32]> =
                chunk.iter().map(|(_, req)| req.tokens.as_slice()).collect();
            while views.len() < exec_b {
                views.push(chunk[0].1.tokens.as_slice());
            }
            let fwd = match engine.forward_batch(variant, kernel, &views, bucket) {
                Ok(f) => f,
                Err(_) => {
                    // Shared dispatch failed: retry each lane unbatched so
                    // one bad group member can't sink its co-batchees.
                    for (i, req) in chunk {
                        events[*i] = Some(run_single(
                            engine, &mut *sessions[*i], req, &mut stats, &mut timelines,
                            collect_obs,
                        ));
                    }
                    continue;
                }
            };
            stats.dispatches += 1;
            stats.lanes_real += m;
            stats.lanes_executed += exec_b;
            if m > 1 {
                stats.fused_dispatches += 1;
            }
            // The full exec_b-lane batched dispatch: the PU timeline is
            // occupied for its entire duration; each of the m sharing
            // sessions is charged an even share of it (padding lanes are
            // overhead the sharers absorb; no simulated time vanishes).
            //
            // Lanes with resident KV pay the incremental per-lane cost
            // over their own cached extent (padding lanes replicate lane
            // 0's, matching the replicated tokens). All-cold chunks take
            // the historical batched pricing path so `kv_cache: off`
            // stays bit-identical by construction.
            let any_cached = chunk.iter().any(|(_, req)| req.kv_cached > 0);
            let duration = if any_cached {
                let mut d = lat.dispatch_overhead(pu);
                for lane in 0..exec_b {
                    let cached = chunk
                        .get(lane)
                        .map_or(chunk[0].1.kv_cached, |(_, req)| req.kv_cached);
                    d += lat.incremental_lane_cost(&spec, variant.scheme, pu, bucket, cached);
                }
                d
            } else {
                lat.batched_forward_latency(&spec, variant.scheme, pu, bucket, exec_b)
            };
            if collect_obs {
                stats.observations.push(DispatchObs {
                    variant,
                    kernel,
                    bucket,
                    pu,
                    lanes: exec_b,
                    flops: spec.forward_flops(bucket),
                    duration_s: duration,
                });
            }
            let sim_share = duration / m as f64;
            let real_share = fwd.elapsed_s / m as f64;
            let span = timelines.as_deref_mut().map(|tl| {
                // The shared dispatch can start only once every sharer's
                // inputs exist (the readiness rule's `inputs_ready`).
                let inputs_ready = chunk
                    .iter()
                    .map(|(i, _)| sessions[*i].ready_s())
                    .fold(0.0, f64::max);
                tl.dispatch(pu.id(), inputs_ready, duration)
            });
            for (row, (i, _req)) in chunk.iter().enumerate() {
                let reply = EngineReply::Forward(ForwardReply {
                    fwd: &fwd,
                    row,
                    sim_s: sim_share,
                    real_s: real_share,
                });
                events[*i] = Some(match sessions[*i].apply(engine, reply) {
                    Ok(StepProgress::Round(out)) => TickEvent::Round(out),
                    Ok(StepProgress::Pending) => TickEvent::Pending,
                    Err(_) => TickEvent::Failed,
                });
                if let Some(span) = span {
                    sessions[*i].set_ready_s(span.end);
                }
            }
        }
    }

    let events = events
        .into_iter()
        .map(|e| e.unwrap_or(TickEvent::Pending))
        .collect();
    (events, stats)
}

/// Execute one request unbatched through the session's own singleton path,
/// scheduling it on the routed PU timeline when one is supplied (mono
/// rounds occupy — block — the secondary mapped PU too).
fn run_single(
    engine: &Engine,
    session: &mut DecodeSession,
    req: &EngineRequest,
    stats: &mut TickStats,
    timelines: &mut Option<&mut PuTimelines>,
    collect_obs: bool,
) -> TickEvent {
    let sim_before = session.outcome().sim_s;
    match session.execute(engine, req) {
        Ok(progress) => {
            stats.dispatches += 1;
            // A tree dispatch fills its own lanes (the session batches its
            // tree-node prefixes itself); padding to the compiled batch
            // sizes is accounted per round via StepOutcome's tree lane
            // counters, so the tick stats count the real lanes here.
            let kind_lanes = match req.kind {
                RequestKind::TreeForward { lanes, .. } => lanes,
                _ => 1,
            };
            stats.lanes_real += kind_lanes;
            stats.lanes_executed += kind_lanes;
            let duration = (session.outcome().sim_s - sim_before).max(0.0);
            if collect_obs {
                match req.kind {
                    RequestKind::Forward { variant, kernel, bucket } => {
                        if let Ok(spec) = engine.manifest.model_for(variant) {
                            stats.observations.push(DispatchObs {
                                variant,
                                kernel,
                                bucket,
                                pu: req.route.primary,
                                lanes: 1,
                                flops: spec.forward_flops(bucket),
                                duration_s: duration,
                            });
                        }
                    }
                    // Tree dispatches feed the calibration too: the whole
                    // (possibly chunked) multi-lane duration against the
                    // lanes × flops feature, so the online model prices
                    // tree shapes from what actually ran.
                    RequestKind::TreeForward { variant, kernel, bucket, lanes } => {
                        if let Ok(spec) = engine.manifest.model_for(variant) {
                            stats.observations.push(DispatchObs {
                                variant,
                                kernel,
                                bucket,
                                pu: req.route.primary,
                                lanes,
                                flops: spec.forward_flops(bucket),
                                duration_s: duration,
                            });
                        }
                    }
                    RequestKind::MonoStep { .. } => {}
                }
            }
            if let Some(tl) = timelines.as_deref_mut() {
                let blocked_buf;
                let blocked: &[PuId] = match req.route.blocks {
                    Some(b) => {
                        blocked_buf = [b.id()];
                        &blocked_buf
                    }
                    None => &[],
                };
                let span = tl.dispatch_blocking(
                    req.route.primary.id(),
                    blocked,
                    session.ready_s(),
                    duration,
                );
                session.set_ready_s(span.end);
            }
            match progress {
                StepProgress::Round(out) => TickEvent::Round(out),
                StepProgress::Pending => TickEvent::Pending,
            }
        }
        Err(_) => TickEvent::Failed,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_planning_pads_to_compiled_sizes() {
        // One request: smallest compiled size that fits is the batch-1
        // artifact — singleton dispatch, no padding.
        assert_eq!(plan_chunks(1, &[1, 4]), vec![(1, 1)]);
        // Partial group: padded up to the compiled batch.
        assert_eq!(plan_chunks(3, &[1, 4]), vec![(3, 4)]);
        assert_eq!(plan_chunks(4, &[1, 4]), vec![(4, 4)]);
        // Overflow: filled chunks of the largest size, then the tail.
        assert_eq!(plan_chunks(6, &[1, 4]), vec![(4, 4), (2, 4)]);
        assert_eq!(plan_chunks(9, &[1, 4]), vec![(4, 4), (4, 4), (1, 1)]);
        // Batch-1-only kernel (Pallas): everything degenerates to
        // singleton dispatches.
        assert_eq!(plan_chunks(3, &[1]), vec![(1, 1), (1, 1), (1, 1)]);
        // Richer ladders pick the tightest fit per chunk.
        assert_eq!(plan_chunks(5, &[1, 2, 4]), vec![(4, 4), (1, 1)]);
        assert_eq!(plan_chunks(3, &[2, 8]), vec![(3, 8)]);
    }

    #[test]
    fn chunks_cover_every_request_exactly_once() {
        for sizes in [vec![1], vec![1, 4], vec![1, 2, 8], vec![4]] {
            for k in 1..=20usize {
                let chunks = plan_chunks(k, &sizes);
                let total: usize = chunks.iter().map(|&(m, _)| m).sum();
                assert_eq!(total, k, "k={k} sizes={sizes:?}");
                for &(m, exec_b) in &chunks {
                    assert!(m >= 1 && m <= exec_b, "k={k} sizes={sizes:?}");
                    assert!(
                        sizes.contains(&exec_b),
                        "exec_b {exec_b} not a compiled size"
                    );
                }
            }
        }
    }
}
