//! **Quarantined legacy path** — lockstep batched decode for
//! non-speculative (baseline) requests, the *static*-batching reference
//! implementation. Production serving never routes here: the only
//! entries are the `fuse: false` A/B knob and the accounting tests in
//! `tests/fused_e2e.rs`. Kept (under this deliberately unglamorous
//! name) because the measured lockstep tail is the baseline the fused
//! executor's win is quantified against.
//!
//! Without a KV cache, batching is lockstep full-sequence re-encoding:
//! requests grouped into one `forward_batch` call advance one token each
//! per step, padded to a shared bucket. Finished sequences are carried as
//! padding until the whole batch drains (classic static-batching tail —
//! measured and reported, which is exactly why speculative decoding is the
//! more interesting single-stream path on edge).
//!
//! The default serving path no longer uses this module: baseline
//! batching is folded onto the coordinator's fused executor
//! ([`crate::coordinator::fuser`]), which recovers the same shared
//! dispatches *without* the lockstep tail (sessions retire at their own
//! EOS). This stays as the measured static-batching baseline, served
//! when the `fuse: false` A/B knob is set.
//!
//! **Amortization rule.** Artifacts exist only for the manifest's compiled
//! batch sizes, so `b` real requests execute as `exec_b ≥ b` padded lanes.
//! The *executed* cost (the full `exec_b`-lane dispatch, real wall-clock
//! and simulated alike) is split evenly across the `b` real requests:
//! filler lanes are pure padding overhead and their cost must land on
//! someone, or total charged time would undercount total spent time.

use crate::config::KernelPath;
use crate::models::VariantKey;
use crate::runtime::Engine;
use crate::tokenizer::EOS_ID;

/// Outcome for one batched request.
#[derive(Debug, Clone)]
pub struct BatchItemOutcome {
    pub tokens: Vec<u32>,
    /// Shared decode steps this request was live for (its lockstep
    /// "rounds" — one token attempt per step).
    pub target_calls: usize,
    /// The sequence ended on EOS (vs running out of budget/bucket).
    pub eos: bool,
    pub real_s: f64,
    /// Simulated seconds attributed to this item: executed `exec_b`-lane
    /// dispatch cost / `b` real requests (see the module-level
    /// amortization rule).
    pub sim_s: f64,
}

/// Lockstep batched greedy decode of up to `prompts.len()` requests.
///
/// `sim_forward(bucket, exec_b)` supplies the simulated cost of one
/// batched forward over the **executed** lane count `exec_b` (the compiled
/// batch size actually dispatched, padding included) — typically
/// [`crate::hetero::LatencyModel::batched_forward_latency`].
pub fn batched_baseline(
    engine: &Engine,
    target: VariantKey,
    kernel: KernelPath,
    prompts: &[Vec<u32>],
    max_new: usize,
    sim_forward: &dyn Fn(usize, usize) -> f64,
) -> anyhow::Result<Vec<BatchItemOutcome>> {
    let b = prompts.len();
    anyhow::ensure!(b >= 1);
    // Artifacts exist only for the manifest's batch sizes; pad a partial
    // batch (e.g. 3 requests with {1,4} compiled) by replicating the first
    // prompt — the filler lanes' outputs are discarded below.
    let exec_b = engine
        .manifest
        .batch_sizes
        .iter()
        .copied()
        .filter(|&n| n >= b)
        .min()
        .ok_or_else(|| anyhow::anyhow!(
            "batch {b} exceeds the largest compiled batch size"))?;
    let max_total = engine.manifest.largest_bucket();
    let mut seqs: Vec<Vec<u32>> = prompts.to_vec();
    while seqs.len() < exec_b {
        seqs.push(prompts[0].clone());
    }
    let mut done = vec![false; b];
    let mut out: Vec<BatchItemOutcome> = (0..b)
        .map(|_| BatchItemOutcome {
            tokens: vec![],
            target_calls: 0,
            eos: false,
            real_s: 0.0,
            sim_s: 0.0,
        })
        .collect();

    for _ in 0..max_new {
        if done.iter().all(|&d| d) {
            break;
        }
        let longest = seqs.iter().map(Vec::len).max().unwrap();
        if longest + 1 > max_total {
            break;
        }
        let bucket = engine.bucket_for(longest)?;
        let views: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
        let fwd = engine.forward_batch(target, kernel, &views, bucket)?;
        // Charge what actually ran: the exec_b-lane dispatch, split over
        // the b real requests (module-level amortization rule). The old
        // code priced the dispatch at b lanes while executing exec_b.
        let sim = sim_forward(bucket, exec_b);
        // Filler lanes (i >= b) track lane 0 but produce no outcome.
        for i in b..exec_b {
            if !done[0] {
                let pos = seqs[i].len() - 1;
                let nxt = fwd.argmax(i, pos);
                if nxt != EOS_ID && seqs[i].len() + 1 < max_total {
                    seqs[i].push(nxt);
                }
            }
        }
        for i in 0..b {
            out[i].real_s += fwd.elapsed_s / b as f64;
            out[i].sim_s += sim / b as f64;
            if done[i] {
                continue;
            }
            out[i].target_calls += 1;
            let pos = seqs[i].len() - 1;
            let nxt = fwd.argmax(i, pos);
            if nxt == EOS_ID || seqs[i].len() + 1 >= max_total {
                out[i].eos = nxt == EOS_ID;
                done[i] = true;
                continue;
            }
            seqs[i].push(nxt);
            out[i].tokens.push(nxt);
        }
    }
    Ok(out)
}
