//! Bounded MPMC request queue with blocking pop and reject-on-full push —
//! the backpressure point of the serving pipeline.

use crate::workload::Request;
use std::collections::VecDeque;
use std::sync::{mpsc, Condvar, Mutex};
use std::time::Instant;

/// A queued request plus its response channel(s).
pub struct QueueItem {
    pub request: Request,
    pub enqueued: Instant,
    pub respond: mpsc::Sender<super::EngineResponse>,
    /// Optional incremental channel: the worker emits one [`TokenFrame`]
    /// per round as tokens commit (streaming responses).
    ///
    /// [`TokenFrame`]: super::TokenFrame
    pub token_tx: Option<mpsc::Sender<super::TokenFrame>>,
}

/// Bounded FIFO. `push` fails when full (callers surface 429-style
/// rejection); `pop` blocks until an item arrives or the queue is closed.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner {
    items: VecDeque<QueueItem>,
    closed: bool,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking push; Err(item) when full or closed.
    pub fn push(&self, item: QueueItem) -> Result<(), QueueItem> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.items.len() >= self.capacity {
            return Err(item);
        }
        g.items.push_back(item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; None when the queue is closed and drained.
    pub fn pop(&self) -> Option<QueueItem> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Non-blocking pop; None when the queue is momentarily empty (the
    /// round-level scheduler tops up in-flight sessions between rounds
    /// without stalling the ones already live).
    pub fn try_pop(&self) -> Option<QueueItem> {
        self.inner.lock().unwrap().items.pop_front()
    }

    /// Pop up to `max` items without blocking beyond the first (dynamic
    /// batching: take what's there, don't wait for stragglers).
    pub fn pop_batch(&self, max: usize) -> Vec<QueueItem> {
        let first = match self.pop() {
            Some(f) => f,
            None => return Vec::new(),
        };
        let mut batch = vec![first];
        if max > 1 {
            let mut g = self.inner.lock().unwrap();
            while batch.len() < max {
                match g.items.pop_front() {
                    Some(i) => batch.push(i),
                    None => break,
                }
            }
        }
        batch
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn item(id: u64) -> QueueItem {
        let (tx, _rx) = mpsc::channel();
        QueueItem {
            request: Request {
                id,
                task: "t".into(),
                prompt: vec![1],
                truth: String::new(),
                arrival_s: 0.0,
            },
            enqueued: Instant::now(),
            respond: tx,
            token_tx: None,
        }
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(10);
        q.push(item(1)).ok().unwrap();
        q.push(item(2)).ok().unwrap();
        assert_eq!(q.pop().unwrap().request.id, 1);
        assert_eq!(q.pop().unwrap().request.id, 2);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let q = RequestQueue::new(2);
        assert!(q.push(item(1)).is_ok());
        assert!(q.push(item(2)).is_ok());
        assert!(q.push(item(3)).is_err());
        q.pop();
        assert!(q.push(item(4)).is_ok());
    }

    #[test]
    fn close_unblocks_poppers() {
        let q = Arc::new(RequestQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(50));
        q.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn pop_batch_takes_available() {
        let q = RequestQueue::new(10);
        for i in 0..5 {
            q.push(item(i)).ok().unwrap();
        }
        let b = q.pop_batch(3);
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].request.id, 0);
        let b = q.pop_batch(10);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn try_pop_never_blocks() {
        let q = RequestQueue::new(4);
        assert!(q.try_pop().is_none());
        q.push(item(1)).ok().unwrap();
        assert_eq!(q.try_pop().unwrap().request.id, 1);
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn push_after_close_rejected() {
        let q = RequestQueue::new(4);
        q.close();
        assert!(q.push(item(1)).is_err());
    }
}
