//! Bounded MPMC request queue with blocking pop and reject-on-full push —
//! the backpressure point of the serving pipeline.
//!
//! Since the request-lifecycle API v2 the queue is *priority-ordered*:
//! items are admitted `Interactive` before `Batch`
//! ([`SloClass`](crate::api::SloClass)), higher
//! [`priority`](crate::api::GenOptions::priority) first within a class,
//! FIFO within equal keys — so default-option traffic (everything
//! `Interactive` at priority 0) pops in exactly the seed FIFO order.
//! Queued items also carry their request's lifecycle state: the worker
//! consults [`QueueItem::cancelled`] and [`QueueItem::deadline_expired`]
//! at admission and sheds dead items instead of decoding for nobody
//! (deadline-based admission shedding).

use crate::api::GenerationRequest;
use std::sync::{mpsc, Condvar, Mutex};
use std::time::Instant;

use super::CancelGuard;

/// A queued request plus its response channel(s) and lifecycle state.
pub struct QueueItem {
    pub request: GenerationRequest,
    pub enqueued: Instant,
    /// FIFO tiebreak within an (SLO class, priority) level, assigned by
    /// the queue at push time.
    seq: u64,
    pub respond: mpsc::Sender<super::EngineResponse>,
    /// Incremental channel: the worker emits one [`TokenFrame`] per round
    /// as tokens commit (every handle gets one; `None` only for callers
    /// that explicitly opt out).
    ///
    /// [`TokenFrame`]: super::TokenFrame
    pub token_tx: Option<mpsc::Sender<super::TokenFrame>>,
    /// Cancellation flag + registry cleanup guard.
    pub cancel: CancelGuard,
}

impl QueueItem {
    /// Item with a detached (un-registered) cancellation flag — tests,
    /// benches and drivers that never cancel.
    pub fn new(
        request: GenerationRequest,
        respond: mpsc::Sender<super::EngineResponse>,
        token_tx: Option<mpsc::Sender<super::TokenFrame>>,
    ) -> QueueItem {
        Self::with_cancel(request, respond, token_tx, CancelGuard::detached())
    }

    /// Item wired to a coordinator-registered cancellation guard.
    pub fn with_cancel(
        request: GenerationRequest,
        respond: mpsc::Sender<super::EngineResponse>,
        token_tx: Option<mpsc::Sender<super::TokenFrame>>,
        cancel: CancelGuard,
    ) -> QueueItem {
        QueueItem { request, enqueued: Instant::now(), seq: 0, respond, token_tx, cancel }
    }

    /// The request was cancelled while queued.
    pub fn cancelled(&self) -> bool {
        self.cancel.cancelled()
    }

    /// The request's deadline expired before admission (queueing delay
    /// alone already exceeds the budget — nothing decodable remains).
    pub fn deadline_expired(&self) -> bool {
        match self.request.options.deadline_s {
            Some(d) => self.enqueued.elapsed().as_secs_f64() >= d,
            None => false,
        }
    }

    /// Admission order: SLO class first (`Interactive` before `Batch`),
    /// then descending priority, then FIFO.
    fn order_key(&self) -> (u8, i64, u64) {
        (
            self.request.options.slo.index() as u8,
            -(self.request.options.priority as i64),
            self.seq,
        )
    }
}

/// Bounded priority queue. `push` fails when full (callers surface
/// 429-style rejection); `pop` blocks until an item arrives or the queue
/// is closed.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner {
    /// Sorted *descending* by [`QueueItem::order_key`], so the next item
    /// to admit (the minimum key) sits at the back: `Vec::pop` keeps
    /// every pop O(1) while inserts pay the O(n) shift — the right trade
    /// for a pop-heavy serving queue.
    items: Vec<QueueItem>,
    next_seq: u64,
    closed: bool,
}

impl Inner {
    /// Next item in admission order (the minimum key, kept at the back).
    fn take_next(&mut self) -> Option<QueueItem> {
        self.items.pop()
    }
}

impl RequestQueue {
    pub fn new(capacity: usize) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner { items: Vec::new(), next_seq: 0, closed: false }),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Non-blocking push; Err(item) when full or closed. The item is
    /// inserted at its priority position (FIFO within equal keys).
    pub fn push(&self, mut item: QueueItem) -> Result<(), QueueItem> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.items.len() >= self.capacity {
            return Err(item);
        }
        item.seq = g.next_seq;
        g.next_seq += 1;
        let key = item.order_key();
        // Descending order, FIFO within a level: the fresh item's seq
        // makes its key strictly larger than equal-level incumbents', so
        // it lands in front of them and pops after them.
        let pos = g.items.partition_point(|it| it.order_key() > key);
        g.items.insert(pos, item);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; None when the queue is closed and drained.
    pub fn pop(&self) -> Option<QueueItem> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.take_next() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.not_empty.wait(g).unwrap();
        }
    }

    /// Non-blocking pop; None when the queue is momentarily empty (the
    /// round-level scheduler tops up in-flight sessions between rounds
    /// without stalling the ones already live).
    pub fn try_pop(&self) -> Option<QueueItem> {
        self.inner.lock().unwrap().take_next()
    }

    /// Pop up to `max` items without blocking beyond the first (dynamic
    /// batching: take what's there, don't wait for stragglers).
    pub fn pop_batch(&self, max: usize) -> Vec<QueueItem> {
        let first = match self.pop() {
            Some(f) => f,
            None => return Vec::new(),
        };
        let mut batch = vec![first];
        if max > 1 {
            let mut g = self.inner.lock().unwrap();
            while batch.len() < max {
                match g.take_next() {
                    Some(i) => batch.push(i),
                    None => break,
                }
            }
        }
        batch
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{GenOptions, SloClass};
    use crate::workload::Request;
    use std::sync::Arc;

    fn request(id: u64) -> Request {
        Request {
            id,
            task: "t".into(),
            prompt: vec![1],
            truth: String::new(),
            arrival_s: 0.0,
            class: None,
        }
    }

    fn item(id: u64) -> QueueItem {
        let (tx, _rx) = mpsc::channel();
        QueueItem::new(request(id).into(), tx, None)
    }

    fn item_with(id: u64, options: GenOptions) -> QueueItem {
        let (tx, _rx) = mpsc::channel();
        QueueItem::new(
            crate::api::GenerationRequest::from(request(id)).with_options(options),
            tx,
            None,
        )
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(10);
        q.push(item(1)).ok().unwrap();
        q.push(item(2)).ok().unwrap();
        assert_eq!(q.pop().unwrap().request.id, 1);
        assert_eq!(q.pop().unwrap().request.id, 2);
    }

    #[test]
    fn priority_admits_high_before_earlier_low() {
        let q = RequestQueue::new(10);
        q.push(item_with(1, GenOptions { priority: -1, ..GenOptions::default() }))
            .ok()
            .unwrap();
        q.push(item_with(2, GenOptions { priority: -1, ..GenOptions::default() }))
            .ok()
            .unwrap();
        // A later high-priority arrival jumps both earlier ones.
        q.push(item_with(3, GenOptions { priority: 5, ..GenOptions::default() }))
            .ok()
            .unwrap();
        // Default priority (0) sits between.
        q.push(item(4)).ok().unwrap();
        let order: Vec<u64> = (0..4).map(|_| q.pop().unwrap().request.id).collect();
        assert_eq!(order, vec![3, 4, 1, 2]);
    }

    #[test]
    fn interactive_class_outranks_batch_priority() {
        let q = RequestQueue::new(10);
        q.push(item_with(
            1,
            GenOptions { slo: SloClass::Batch, priority: 100, ..GenOptions::default() },
        ))
        .ok()
        .unwrap();
        q.push(item_with(
            2,
            GenOptions { slo: SloClass::Interactive, priority: -100, ..GenOptions::default() },
        ))
        .ok()
        .unwrap();
        // Interactive admits first regardless of numeric priority.
        assert_eq!(q.pop().unwrap().request.id, 2);
        assert_eq!(q.pop().unwrap().request.id, 1);
    }

    #[test]
    fn lifecycle_helpers() {
        let it = item_with(1, GenOptions { deadline_s: Some(0.0), ..GenOptions::default() });
        assert!(it.deadline_expired(), "zero deadline expires immediately");
        let it = item_with(2, GenOptions { deadline_s: Some(1e9), ..GenOptions::default() });
        assert!(!it.deadline_expired());
        let it = item(3);
        assert!(!it.deadline_expired(), "no deadline never expires");
        assert!(!it.cancelled());
        it.cancel.flag().store(true, std::sync::atomic::Ordering::SeqCst);
        assert!(it.cancelled());
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let q = RequestQueue::new(2);
        assert!(q.push(item(1)).is_ok());
        assert!(q.push(item(2)).is_ok());
        assert!(q.push(item(3)).is_err());
        q.pop();
        assert!(q.push(item(4)).is_ok());
    }

    #[test]
    fn close_unblocks_poppers() {
        let q = Arc::new(RequestQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop().is_none());
        std::thread::sleep(std::time::Duration::from_millis(50));
        q.close();
        assert!(h.join().unwrap());
    }

    #[test]
    fn pop_batch_takes_available() {
        let q = RequestQueue::new(10);
        for i in 0..5 {
            q.push(item(i)).ok().unwrap();
        }
        let b = q.pop_batch(3);
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].request.id, 0);
        let b = q.pop_batch(10);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn try_pop_never_blocks() {
        let q = RequestQueue::new(4);
        assert!(q.try_pop().is_none());
        q.push(item(1)).ok().unwrap();
        assert_eq!(q.try_pop().unwrap().request.id, 1);
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn push_after_close_rejected() {
        let q = RequestQueue::new(4);
        q.close();
        assert!(q.push(item(1)).is_err());
    }
}
