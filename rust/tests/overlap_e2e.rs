//! Per-PU timeline end-to-end tests (skipped when `make artifacts` hasn't
//! run):
//!
//! * a deterministic two-session heterogeneous scenario — one session
//!   drafting on the GPU while the other verifies on the CPU cluster —
//!   where the overlapped makespan is strictly below the serialized sum,
//!   with the exact conservation law `makespan = busy_cpu + busy_gpu −
//!   overlap` holding;
//! * `hetero_overlap: false` (serialized timelines) reproduces the
//!   per-session simulated charges and token streams bit-identically —
//!   the timelines are pure observation, the A/B knob changes only the
//!   makespan model;
//! * homogeneous mappings have a single timeline and can never report
//!   overlap;
//! * coordinator-level A/B parity of the `hetero_overlap` knob.

use specedge::config::{ExecMode, KernelPath, RunConfig};
use specedge::coordinator::Coordinator;
use specedge::experiments::overlap::drive_to_completion;
use specedge::hetero::{LatencyModel, Mapping, Platform, PuId, PuTimelines};
use specedge::models::VariantKey;
use specedge::runtime::Engine;
use specedge::spec::{AcceptRule, DecodeOutcome, DecodeSession, DecoderSetup};
use specedge::tokenizer::{Tokenizer, SEP_ID};
use specedge::workload::Request;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn engine() -> Option<Engine> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(dir).expect("engine"))
}

fn setup(gamma: usize, mapping: Mapping) -> DecoderSetup {
    DecoderSetup {
        drafter: VariantKey::parse("drafter_fp").unwrap(),
        target: VariantKey::parse("target_w8a8").unwrap(),
        kernel: KernelPath::Ref,
        mapping,
        gamma,
        rule: AcceptRule::Greedy,
        exec: ExecMode::Modular,
        max_new: 16,
    }
}

fn prompts(engine: &Engine, n: usize) -> Vec<Vec<u32>> {
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec).unwrap();
    let samples: Vec<_> = engine
        .manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .collect();
    assert!(!samples.is_empty(), "eval set has no translate samples");
    (0..n)
        .map(|i| {
            let s = samples[i % samples.len()];
            let mut ids = tokenizer.encode(&s.prompt, true).unwrap();
            ids.push(SEP_ID);
            ids
        })
        .collect()
}

/// Drive staggered-γ sessions to completion through the fused executor on
/// the given timeline mode; returns the final timelines and outcomes.
fn drive(
    engine: &Engine,
    ps: &[Vec<u32>],
    gammas: &[usize],
    mapping: Mapping,
    overlapped: bool,
) -> (PuTimelines, Vec<DecodeOutcome>) {
    let lat = LatencyModel::new(Platform::imx95());
    let mut tl = if overlapped {
        PuTimelines::new()
    } else {
        PuTimelines::serialized()
    };
    let mut sessions: Vec<DecodeSession> = ps
        .iter()
        .zip(gammas)
        .map(|(p, &g)| DecodeSession::new(engine, lat.clone(), setup(g, mapping), true, p))
        .collect();
    drive_to_completion(engine, &lat, &mut sessions, &mut tl).expect("no session may fail");
    let outcomes = sessions.into_iter().map(DecodeSession::into_outcome).collect();
    (tl, outcomes)
}

#[test]
fn two_session_hetero_overlap_beats_serialized_sum() {
    let Some(engine) = engine() else { return };
    let ps = prompts(&engine, 2);
    // Staggered draft windows de-phase the two sessions, so session A
    // drafts on the GPU while session B verifies on the CPU cluster.
    let gammas = [2usize, 5];
    let mapping = Mapping::heterogeneous(1);

    let (serial, serial_out) = drive(&engine, &ps, &gammas, mapping, false);
    let (over, over_out) = drive(&engine, &ps, &gammas, mapping, true);

    // The serialized baseline: single-clock behavior — makespan is the
    // sum of every dispatch duration, nothing overlaps.
    let serial_busy = serial.busy(PuId::Cpu) + serial.busy(PuId::Gpu);
    assert!(
        (serial.makespan() - serial_busy).abs() < 1e-9 * serial_busy.max(1.0),
        "serialized makespan {} != busy sum {serial_busy}",
        serial.makespan()
    );
    assert_eq!(serial.overlap_s(), 0.0);

    // Identical dispatches on both timelines: per-PU busy conserved.
    assert!((over.busy(PuId::Cpu) - serial.busy(PuId::Cpu)).abs() < 1e-12);
    assert!((over.busy(PuId::Gpu) - serial.busy(PuId::Gpu)).abs() < 1e-12);

    // The acceptance criterion: with a heterogeneous mapping and ≥ 2
    // in-flight sessions, the overlapped makespan is strictly below the
    // serialized one, by exactly the overlapped seconds (2-PU
    // inclusion–exclusion: makespan = Σ busy − overlap).
    assert!(over.overlap_s() > 0.0, "no draft/verify overlap materialized");
    assert!(
        over.makespan() < serial.makespan(),
        "overlap {} !< serialized {}",
        over.makespan(),
        serial.makespan()
    );
    let expect = serial_busy - over.overlap_s();
    assert!(
        (over.makespan() - expect).abs() < 1e-9 * serial_busy.max(1.0),
        "makespan {} != busy − overlap = {expect}",
        over.makespan()
    );

    // The timelines are pure observation: token streams and per-session
    // simulated charges are bit-identical across modes (`hetero_overlap:
    // false` reproduces the pre-overlap timings exactly).
    for (a, b) in serial_out.iter().zip(&over_out) {
        assert_eq!(a.tokens, b.tokens, "timeline mode changed tokens");
        assert_eq!(a.sim_s.to_bits(), b.sim_s.to_bits(), "sim_s not bit-identical");
        assert_eq!(a.n_rounds, b.n_rounds);
    }
}

#[test]
fn homogeneous_mapping_never_overlaps() {
    let Some(engine) = engine() else { return };
    let ps = prompts(&engine, 2);
    let (tl, _) = drive(&engine, &ps, &[2, 5], Mapping::homogeneous(2), true);
    // One physical PU: its timeline serializes; overlap is impossible and
    // the makespan equals the CPU busy time.
    assert_eq!(tl.overlap_s(), 0.0);
    assert_eq!(tl.busy(PuId::Gpu), 0.0);
    assert!((tl.makespan() - tl.busy(PuId::Cpu)).abs() < 1e-9);
}

fn coord_cfg(hetero_overlap: bool) -> RunConfig {
    RunConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        max_new_tokens: 12,
        gamma: Some(3),
        kernel_path: KernelPath::Ref,
        max_inflight: 4,
        hetero_overlap,
        ..RunConfig::default()
    }
}

fn run_coord(hetero_overlap: bool, n: usize) -> (Vec<Vec<u32>>, specedge::metrics::Report) {
    let coord =
        Arc::new(Coordinator::start(coord_cfg(hetero_overlap), Platform::imx95()).unwrap());
    let manifest = specedge::runtime::Manifest::load(Path::new("artifacts")).unwrap();
    let tokenizer = Tokenizer::from_manifest(&manifest.tokenizer_spec).unwrap();
    let samples: Vec<_> = manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .collect();
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = samples[i % samples.len()];
            let mut prompt = tokenizer.encode(&s.prompt, true).unwrap();
            prompt.push(SEP_ID);
            coord.submit(Request {
                id: i as u64,
                task: "translate".into(),
                prompt,
                truth: String::new(),
                arrival_s: 0.0,
                class: None,
            })
        })
        .collect();
    let mut outs: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    outs.sort_by_key(|o| o.id);
    let report = coord.metrics.snapshot();
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
    (outs.into_iter().map(|o| o.tokens).collect(), report)
}

#[test]
fn coordinator_hetero_overlap_knob_is_pure_observation() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    // (Bit-identical per-session sim_s parity across timeline modes is
    // asserted at the fuser level above, where dispatch grouping is
    // deterministic; the coordinator's admission timing can change which
    // sessions share a dispatch run-to-run, which re-splits — without
    // changing in total — the simulated charges.)
    let (serialized, serial_report) = run_coord(false, 6);
    let (overlapped, over_report) = run_coord(true, 6);
    // A/B parity: the knob never changes what is decoded.
    assert_eq!(serialized, overlapped, "hetero_overlap knob perturbed decoding");
    // Both modes observe timelines and per-request timeline latencies.
    assert!(serial_report.makespan_s > 0.0);
    assert!(over_report.makespan_s > 0.0);
    assert_eq!(serial_report.tl_latency.n, 6);
    assert_eq!(over_report.tl_latency.n, 6);
    // Serialized timelines never overlap, and conserve makespan = Σ busy.
    assert_eq!(serial_report.overlap_s, 0.0);
    let busy_sum: f64 = serial_report.pu_busy.iter().sum();
    assert!(
        (serial_report.makespan_s - busy_sum).abs() < 1e-9 * busy_sum.max(1.0),
        "serialized makespan {} != busy sum {busy_sum}",
        serial_report.makespan_s
    );
    // The overlapped mode can only hide time, never add it.
    let over_busy: f64 = over_report.pu_busy.iter().sum();
    assert!(over_report.makespan_s <= over_busy + 1e-9);
}
