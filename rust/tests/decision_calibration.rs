//! Decision-layer tests: calibration convergence (property-based, against
//! randomly perturbed platforms), analytic-model parity with the seed's
//! DSE Tables II/III decisions, and coordinator-level A/B parity of the
//! `decision` knob (the last needs `make artifacts` and is skipped
//! without them).

use specedge::config::{DecisionMode, KernelPath, RunConfig};
use specedge::coordinator::Coordinator;
use specedge::decision::{CalibratedModel, CostModel, DispatchObs};
use specedge::dse::{self, PairConfig};
use specedge::hetero::{LatencyModel, Mapping, Platform, PuAssignment};
use specedge::models::{ModelSpec, Scheme, VariantKey};
use specedge::tokenizer::{Tokenizer, SEP_ID};
use specedge::util::rng::Rng;
use specedge::workload::Request;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn specs() -> (ModelSpec, ModelSpec) {
    (
        ModelSpec {
            name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
            ffn_dim: 256, vocab: 48, param_count: 230_880,
        },
        ModelSpec {
            name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
            ffn_dim: 352, vocab: 48, param_count: 816_256,
        },
    )
}

fn pair() -> PairConfig {
    let (d, t) = specs();
    PairConfig {
        target: t,
        target_scheme: Scheme::W8a8,
        drafter: d,
        drafter_scheme: Scheme::Fp,
    }
}

// ---- calibration convergence (property-based) ---------------------------

/// Drive the calibrated model with dispatch durations sampled from a
/// platform whose FLOPs rates and dispatch boundaries are perturbed by up
/// to ±30% from the analytic prior; the fitted cost coefficient must land
/// within 5% of the perturbed ground truth.
#[test]
fn prop_calibration_converges_to_perturbed_ground_truth() {
    let (d, t) = specs();
    let drafter_key = VariantKey::parse("drafter_fp").unwrap();
    let target_key = VariantKey::parse("target_w8a8").unwrap();
    for case in 0..100u64 {
        let seed = 0xCA11B ^ (case * 0x100001b3);
        let mut rng = Rng::new(seed);
        let mut perturb = || 0.7 + 0.6 * rng.f64(); // U[0.7, 1.3]
        let mut p = Platform::imx95();
        p.cpu.peak_gflops_per_core *= perturb();
        p.gpu.peak_gflops *= perturb();
        p.cpu.dispatch_overhead_s *= perturb();
        p.gpu.dispatch_overhead_s *= perturb();
        let truth = LatencyModel::new(p);
        let calib = CalibratedModel::new(LatencyModel::new(Platform::imx95()));

        // The observation feed: both variants on their heterogeneous-
        // mapping PUs, across buckets and lane counts (as the fused
        // executor would report them).
        let feeds: [(VariantKey, &ModelSpec, Scheme, PuAssignment); 2] = [
            (drafter_key, &d, Scheme::Fp, PuAssignment::Gpu),
            (target_key, &t, Scheme::W8a8, PuAssignment::Cpu { cores: 1 }),
        ];
        for _rep in 0..2 {
            for &(key, spec, scheme, pu) in &feeds {
                for bucket in [16usize, 64, 128] {
                    for lanes in [1usize, 4] {
                        calib.observe(&DispatchObs {
                            variant: key,
                            kernel: KernelPath::Ref,
                            bucket,
                            pu,
                            lanes,
                            flops: spec.forward_flops(bucket),
                            duration_s: truth
                                .batched_forward_latency(spec, scheme, pu, bucket, lanes),
                        });
                    }
                }
            }
        }
        let m = Mapping::heterogeneous(1);
        let c_fit = calib.cost_coefficient((&d, Scheme::Fp), (&t, Scheme::W8a8), m, 64);
        let c_true = truth.cost_coefficient((&d, Scheme::Fp), (&t, Scheme::W8a8), m, 64);
        let rel = (c_fit - c_true).abs() / c_true;
        assert!(
            rel < 0.05,
            "case {case} (seed {seed:#x}): fitted c {c_fit} vs true {c_true} \
             (rel err {rel:.4})"
        );
        assert_eq!(calib.report().fitted_keys, 2);
    }
}

// ---- analytic parity with the seed's DSE decisions ----------------------

/// The decision engine scores candidates through `&dyn CostModel`; that
/// path — and the calibrated model before any observation — must
/// reproduce the *exact* candidate set and γ* choices the seed's direct
/// LatencyModel search produced (Tables II and III).
#[test]
fn analytic_decision_layer_reproduces_seed_dse_tables() {
    let lat = LatencyModel::new(Platform::imx95());
    let as_dyn: &dyn CostModel = &lat;
    let empty_calib = CalibratedModel::new(lat.clone());
    let p = pair();
    for alpha in [0.90f64, 0.17] {
        let direct = dse::explore_all(&lat, &p, alpha, 63);
        let through_dyn = dse::explore_all(as_dyn, &p, alpha, 63);
        let through_calib = dse::explore_all(&empty_calib, &p, alpha, 63);
        assert_eq!(direct.len(), through_dyn.len());
        assert_eq!(direct.len(), through_calib.len());
        for (v, a) in direct.iter().enumerate() {
            for b in [&through_dyn[v], &through_calib[v]] {
                assert_eq!(a.best.variant, b.best.variant);
                assert_eq!(a.best.mapping, b.best.mapping, "variant {}", v + 1);
                assert_eq!(a.best.gamma, b.best.gamma, "variant {}", v + 1);
                assert_eq!(
                    a.best.speedup.to_bits(),
                    b.best.speedup.to_bits(),
                    "variant {}",
                    v + 1
                );
                assert_eq!(a.all.len(), b.all.len());
                for (ca, cb) in a.all.iter().zip(&b.all) {
                    assert_eq!(ca.mapping, cb.mapping);
                    assert_eq!(ca.gamma, cb.gamma);
                    assert_eq!(ca.infeasible, cb.infeasible);
                    assert_eq!(ca.c.to_bits(), cb.c.to_bits());
                }
            }
        }
    }
    // And the seed's Table II/III anchors hold through the trait path.
    let t2 = dse::explore_all(as_dyn, &p, 0.90, 63);
    let v1 = &t2[0].best;
    assert!(v1.mapping.is_heterogeneous(), "{v1:?}");
    assert!(v1.gamma == 4 || v1.gamma == 5, "{v1:?}");
    assert!((v1.speedup - 1.68).abs() < 0.05, "S = {}", v1.speedup);
    for v in [2usize, 3, 5] {
        assert_eq!(t2[v].best.gamma, 0, "variant {}", v + 1);
    }
    for d in dse::explore_all(as_dyn, &p, 0.17, 63) {
        assert_eq!(d.best.gamma, 0);
    }
}

// ---- coordinator-level knob parity (needs artifacts) --------------------

fn coord_cfg(decision: DecisionMode, repartition_every: usize) -> RunConfig {
    RunConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        max_new_tokens: 12,
        gamma: Some(3),
        kernel_path: KernelPath::Ref,
        max_inflight: 4,
        decision,
        repartition_every,
        ..RunConfig::default()
    }
}

fn run_coord(cfg: RunConfig, n: usize) -> (Vec<Vec<u32>>, specedge::metrics::Report) {
    let coord = Arc::new(Coordinator::start(cfg, Platform::imx95()).unwrap());
    let manifest = specedge::runtime::Manifest::load(Path::new("artifacts")).unwrap();
    let tokenizer = Tokenizer::from_manifest(&manifest.tokenizer_spec).unwrap();
    let samples: Vec<_> = manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .collect();
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = samples[i % samples.len()];
            let mut prompt = tokenizer.encode(&s.prompt, true).unwrap();
            prompt.push(SEP_ID);
            coord.submit(Request {
                id: i as u64,
                task: "translate".into(),
                prompt,
                truth: String::new(),
                arrival_s: 0.0,
                class: None,
            })
        })
        .collect();
    let mut outs: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    outs.sort_by_key(|o| o.id);
    let report = coord.metrics.snapshot();
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
    (outs.into_iter().map(|o| o.tokens).collect(), report)
}

#[test]
fn decision_knob_is_pure_observation_for_token_streams() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    // Analytic default vs analytic with an aggressive re-partition cadence
    // (which must stay inert under the analytic model) vs calibrated with
    // re-partitioning off: all three decode identical token streams.
    let (a, ra) = run_coord(coord_cfg(DecisionMode::Analytic, 64), 6);
    let (b, rb) = run_coord(coord_cfg(DecisionMode::Analytic, 2), 6);
    let (c, rc) = run_coord(coord_cfg(DecisionMode::Calibrated, 0), 6);
    assert_eq!(a, b, "repartition cadence perturbed analytic decoding");
    assert_eq!(a, c, "calibrated model perturbed fixed-gamma decoding");
    assert_eq!(ra.tokens_out, rc.tokens_out);
    // The calibration feed only consumes observations in calibrated mode.
    assert_eq!(ra.calibration_obs, 0, "analytic mode must not calibrate");
    assert_eq!(rb.calibration_obs, 0);
    assert!(rc.calibration_obs > 0, "calibrated mode saw no observations");
    // Fixed-γ configs never ride the silent prior.
    assert_eq!(ra.prior_decisions, 0);
}
