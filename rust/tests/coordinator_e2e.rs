//! Serving-stack end-to-end tests: coordinator + workers + server over the
//! real artifacts (skipped when `make artifacts` hasn't run).

use specedge::config::RunConfig;
use specedge::coordinator::Coordinator;
use specedge::hetero::Platform;
use specedge::server::{Client, Server};
use specedge::tokenizer::Tokenizer;
use specedge::util::json::Json;
use specedge::workload::{Request, Workload};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn have_artifacts() -> bool {
    if Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        false
    }
}

fn cfg() -> RunConfig {
    let mut c = RunConfig::default();
    c.artifacts_dir = PathBuf::from("artifacts");
    c.max_new_tokens = 16;
    c.gamma = Some(3);
    c
}

fn sample_request(id: u64) -> Request {
    let t = Tokenizer::builtin();
    let mut prompt = t.encode("tr: nene caka", true).unwrap();
    prompt.push(specedge::tokenizer::SEP_ID);
    Request { id, task: "translate".into(), prompt, truth: String::new(), arrival_s: 0.0 }
}

#[test]
fn coordinator_serves_requests() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let r = coord.submit_blocking(sample_request(1)).unwrap();
    assert!(!r.tokens.is_empty());
    assert!(r.speculative);
    assert!(r.sim_s > 0.0 && r.real_s > 0.0);
    let report = coord.metrics.snapshot();
    assert_eq!(report.requests, 1);
    coord.shutdown();
}

#[test]
fn coordinator_concurrent_submissions() {
    if !have_artifacts() {
        return;
    }
    let coord = Arc::new(Coordinator::start(cfg(), Platform::imx95()).unwrap());
    let rxs: Vec<_> = (0..4)
        .map(|i| coord.submit(sample_request(i)).unwrap())
        .collect();
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!(!r.completion.is_empty());
    }
    assert_eq!(coord.metrics.snapshot().requests, 4);
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
}

#[test]
fn adaptive_policy_learns_from_served_traffic() {
    if !have_artifacts() {
        return;
    }
    let mut c = cfg();
    c.gamma = None; // adaptive mode
    let coord = Coordinator::start(c, Platform::imx95()).unwrap();
    let before = coord.policy.alpha_estimate("translate");
    for i in 0..3 {
        coord.submit_blocking(sample_request(i)).unwrap();
    }
    let after = coord.policy.alpha_estimate("translate");
    assert!((before - 0.90).abs() < 1e-9, "prior should be 0.90");
    assert_ne!(before, after, "EWMA must move after observations");
    coord.shutdown();
}

#[test]
fn baseline_batching_path() {
    if !have_artifacts() {
        return;
    }
    let mut c = cfg();
    c.speculative = false;
    c.max_batch = 4;
    let coord = Arc::new(Coordinator::start(c, Platform::imx95()).unwrap());
    let rxs: Vec<_> = (0..4)
        .map(|i| coord.submit(sample_request(i)).unwrap())
        .collect();
    let outs: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
    // All four requests served, none speculative, identical prompts ⇒
    // identical completions.
    assert!(outs.iter().all(|o| !o.speculative));
    assert!(outs.windows(2).all(|w| w[0].completion == w[1].completion));
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
}

#[test]
fn server_roundtrip_and_metrics() {
    if !have_artifacts() {
        return;
    }
    let coord = Arc::new(Coordinator::start(cfg(), Platform::imx95()).unwrap());
    let server = Server::start(Arc::clone(&coord), Tokenizer::builtin(), 0).unwrap();
    let port = server.port;

    let mut client = Client::connect(port).unwrap();
    let reply = client.generate("tr: nene caka", "translate").unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    assert!(reply.get("completion").and_then(Json::as_str).is_some());
    assert!(reply.req_f64("sim_ms").unwrap() > 0.0);

    let mut m = Json::obj();
    m.set("cmd", "metrics".into());
    let metrics = client.call(&m).unwrap();
    assert_eq!(metrics.get("requests").and_then(Json::as_usize), Some(1));

    // Bad request surfaces an error, not a hang.
    let mut bad = Json::obj();
    bad.set("task", "x".into());
    let err = client.call(&bad).unwrap();
    assert_eq!(err.get("ok"), Some(&Json::Bool(false)));

    let mut sd = Json::obj();
    sd.set("cmd", "shutdown".into());
    let _ = client.call(&sd);
    server.stop();
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
}

#[test]
fn workload_replay_through_coordinator() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let engine_manifest =
        specedge::runtime::Manifest::load(Path::new("artifacts")).unwrap();
    let tok = Tokenizer::from_manifest(&engine_manifest.tokenizer_spec).unwrap();
    let wl = Workload::from_manifest(&engine_manifest, &tok, Some("translate"), Some(3))
        .unwrap();
    for req in wl.requests {
        let r = coord.submit_blocking(req).unwrap();
        assert!(!r.completion.is_empty());
    }
    let report = coord.metrics.snapshot();
    assert_eq!(report.requests, 3);
    assert!(report.mean_alpha.is_finite());
    coord.shutdown();
}
