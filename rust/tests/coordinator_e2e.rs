//! Serving-stack end-to-end tests: coordinator + workers + server over the
//! real artifacts (skipped when `make artifacts` hasn't run).

use specedge::config::RunConfig;
use specedge::coordinator::Coordinator;
use specedge::hetero::Platform;
use specedge::server::{Client, Server};
use specedge::tokenizer::Tokenizer;
use specedge::util::json::Json;
use specedge::workload::{Request, Workload};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn have_artifacts() -> bool {
    if Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        false
    }
}

fn cfg() -> RunConfig {
    RunConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        max_new_tokens: 16,
        gamma: Some(3),
        ..RunConfig::default()
    }
}

fn sample_request(id: u64) -> Request {
    let t = Tokenizer::builtin();
    let mut prompt = t.encode("tr: nene caka", true).unwrap();
    prompt.push(specedge::tokenizer::SEP_ID);
    Request {
        id,
        task: "translate".into(),
        prompt,
        truth: String::new(),
        arrival_s: 0.0,
        class: None,
    }
}

#[test]
fn coordinator_serves_requests() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let r = coord.submit(sample_request(1)).wait().unwrap();
    assert!(!r.tokens.is_empty());
    assert!(r.speculative);
    assert!(r.sim_s > 0.0 && r.real_s > 0.0);
    // A natural completion carries a natural finish reason.
    assert!(matches!(
        r.finish,
        specedge::api::FinishReason::Stop | specedge::api::FinishReason::Length
    ));
    let report = coord.metrics.snapshot();
    assert_eq!(report.requests, 1);
    coord.shutdown();
}

#[test]
fn coordinator_concurrent_submissions() {
    if !have_artifacts() {
        return;
    }
    let coord = Arc::new(Coordinator::start(cfg(), Platform::imx95()).unwrap());
    let handles: Vec<_> = (0..4)
        .map(|i| coord.submit(sample_request(i)))
        .collect();
    for h in handles {
        let r = h.wait().unwrap();
        assert!(!r.completion.is_empty());
    }
    assert_eq!(coord.metrics.snapshot().requests, 4);
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
}

#[test]
fn adaptive_policy_learns_from_served_traffic() {
    if !have_artifacts() {
        return;
    }
    let mut c = cfg();
    c.gamma = None; // adaptive mode
    let coord = Coordinator::start(c, Platform::imx95()).unwrap();
    let before = coord.policy.alpha_estimate("translate");
    for i in 0..3 {
        coord.submit(sample_request(i)).wait().unwrap();
    }
    let after = coord.policy.alpha_estimate("translate");
    assert!((before - 0.90).abs() < 1e-9, "prior should be 0.90");
    assert_ne!(before, after, "EWMA must move after observations");
    coord.shutdown();
}

#[test]
fn baseline_batching_path() {
    if !have_artifacts() {
        return;
    }
    let mut c = cfg();
    c.speculative = false;
    c.max_batch = 4;
    let coord = Arc::new(Coordinator::start(c, Platform::imx95()).unwrap());
    let handles: Vec<_> = (0..4)
        .map(|i| coord.submit(sample_request(i)))
        .collect();
    let outs: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    // All four requests served, none speculative, identical prompts ⇒
    // identical completions.
    assert!(outs.iter().all(|o| !o.speculative));
    assert!(outs.windows(2).all(|w| w[0].completion == w[1].completion));
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
}

#[test]
fn legacy_lockstep_batching_matches_fused_baseline() {
    if !have_artifacts() {
        return;
    }
    // Same batched-baseline traffic through both executors: the fused
    // scheduler (default) and the legacy lockstep batcher (fuse: false).
    let run = |fuse: bool| -> Vec<specedge::coordinator::EngineResponse> {
        let mut c = cfg();
        c.speculative = false;
        c.max_batch = 4;
        c.fuse = fuse;
        let coord = Arc::new(Coordinator::start(c, Platform::imx95()).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|i| coord.submit(sample_request(i)))
            .collect();
        let mut outs: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
        outs.sort_by_key(|o| o.id);
        Arc::try_unwrap(coord).ok().unwrap().shutdown();
        outs
    };
    let fused = run(true);
    let legacy = run(false);
    assert_eq!(fused.len(), 4);
    for (a, b) in fused.iter().zip(&legacy) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} diverged across executors", a.id);
        assert!(!a.speculative && !b.speculative);
    }
}

#[test]
fn server_roundtrip_and_metrics() {
    if !have_artifacts() {
        return;
    }
    let coord = Arc::new(Coordinator::start(cfg(), Platform::imx95()).unwrap());
    let server = Server::start(Arc::clone(&coord), Tokenizer::builtin(), 0).unwrap();
    let port = server.port;

    let mut client = Client::connect(port).unwrap();
    let reply = client.generate("tr: nene caka", "translate").unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    assert!(reply.get("completion").and_then(Json::as_str).is_some());
    assert!(reply.req_f64("sim_ms").unwrap() > 0.0);

    let mut m = Json::obj();
    m.set("cmd", "metrics".into());
    let metrics = client.call(&m).unwrap();
    assert_eq!(metrics.get("requests").and_then(Json::as_usize), Some(1));

    // Bad request surfaces an error, not a hang.
    let mut bad = Json::obj();
    bad.set("task", "x".into());
    let err = client.call(&bad).unwrap();
    assert_eq!(err.get("ok"), Some(&Json::Bool(false)));

    let mut sd = Json::obj();
    sd.set("cmd", "shutdown".into());
    let _ = client.call(&sd);
    server.stop();
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
}

/// Mixed traffic for the scheduler tests: even ids are speculative-friendly
/// translate requests; odd ids carry a task whose α estimate has been
/// hammered down so the adaptive policy routes them to baseline decode.
fn mixed_request(id: u64) -> Request {
    let t = Tokenizer::builtin();
    let mut prompt = t.encode("tr: nene caka", true).unwrap();
    prompt.push(specedge::tokenizer::SEP_ID);
    let task = if id % 2 == 0 { "translate" } else { "hard-task" };
    Request { id, task: task.into(), prompt, truth: String::new(), arrival_s: 0.0, class: None }
}

fn poison_hard_task(coord: &Coordinator) {
    for _ in 0..60 {
        coord.policy.observe_alpha("hard-task", 0.05);
    }
}

fn run_mixed_batch(max_inflight: usize) -> (Vec<specedge::coordinator::EngineResponse>,
                                            specedge::metrics::Report) {
    let mut c = cfg();
    c.gamma = None; // adaptive: policy decides speculate/γ per task & round
    c.max_inflight = max_inflight;
    let coord = Arc::new(Coordinator::start(c, Platform::imx95()).unwrap());
    poison_hard_task(&coord);
    let handles: Vec<_> = (0..8)
        .map(|i| coord.submit(mixed_request(i)))
        .collect();
    let mut outs: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    outs.sort_by_key(|o| o.id);
    let report = coord.metrics.snapshot();
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
    (outs, report)
}

#[test]
fn scheduler_interleaves_sessions_and_matches_single_inflight() {
    if !have_artifacts() {
        return;
    }
    let (single, single_report) = run_mixed_batch(1);
    let (inter, inter_report) = run_mixed_batch(4);

    // All 8 mixed speculative/baseline requests complete on both schedules.
    assert_eq!(single.len(), 8);
    assert_eq!(inter.len(), 8);
    assert_eq!(inter_report.requests, 8);

    // Greedy decoding is exact, so interleaving must not change any
    // request's tokens versus the run-to-completion schedule.
    for (a, b) in single.iter().zip(&inter) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "request {} diverged", a.id);
    }
    // The poisoned task actually exercised the baseline path and the
    // translate half stayed speculative (mixed traffic, as intended).
    assert!(inter.iter().any(|o| o.speculative));
    assert!(inter.iter().any(|o| !o.speculative));

    // Round-level interleaving is observable in the metrics: with
    // max_inflight=4 at least two sessions must have been live during
    // some round; run-to-completion never exceeds one.
    assert!(inter_report.max_inflight >= 2, "{}", inter_report.max_inflight);
    assert_eq!(single_report.max_inflight, 1);
    assert!(inter_report.rounds > 0);

    // Continuous admission slashes queue wait: later requests no longer
    // sit behind whole earlier requests.
    assert!(
        inter_report.queue_delay.mean < single_report.queue_delay.mean,
        "queue delay should drop: {} !< {}",
        inter_report.queue_delay.mean,
        single_report.queue_delay.mean
    );
}

#[test]
fn streaming_submission_frames_reassemble_final_tokens() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let handle = coord.submit(sample_request(1));
    let mut streamed: Vec<u32> = Vec::new();
    let mut saw_done = false;
    let mut last_round = 0;
    for f in handle.frames() {
        assert!(f.round > last_round, "rounds must be monotonic");
        last_round = f.round;
        streamed.extend(&f.tokens);
        if f.done {
            saw_done = true;
        }
    }
    assert!(saw_done, "stream must end with a done frame");
    let fin = handle.wait().unwrap();
    assert_eq!(streamed, fin.tokens, "frames must reassemble the completion");
    assert!(fin.rounds >= last_round);
    coord.shutdown();
}

#[test]
fn server_streaming_roundtrip() {
    if !have_artifacts() {
        return;
    }
    let coord = Arc::new(Coordinator::start(cfg(), Platform::imx95()).unwrap());
    let server = Server::start(Arc::clone(&coord), Tokenizer::builtin(), 0).unwrap();
    let mut client = Client::connect(server.port).unwrap();

    let (frames, fin) = client.generate_stream("tr: nene caka", "translate").unwrap();
    assert_eq!(fin.get("ok"), Some(&Json::Bool(true)), "{fin}");
    assert_eq!(fin.get("frame").and_then(Json::as_str), Some("final"));
    assert!(!frames.is_empty(), "speculative decode must stream frames");
    let text: String = frames
        .iter()
        .filter_map(|f| f.get("text").and_then(Json::as_str))
        .collect();
    assert_eq!(
        Some(text.as_str()),
        fin.get("completion").and_then(Json::as_str),
        "streamed text must reassemble the final completion"
    );
    // The plain protocol still works on the same connection afterwards.
    let reply = client.generate("tr: nene caka", "translate").unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");

    let mut sd = Json::obj();
    sd.set("cmd", "shutdown".into());
    let _ = client.call(&sd);
    server.stop();
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
}

#[test]
fn workload_replay_through_coordinator() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let engine_manifest =
        specedge::runtime::Manifest::load(Path::new("artifacts")).unwrap();
    let tok = Tokenizer::from_manifest(&engine_manifest.tokenizer_spec).unwrap();
    let wl = Workload::from_manifest(&engine_manifest, &tok, Some("translate"), Some(3))
        .unwrap();
    for req in wl.requests {
        let r = coord.submit(req).wait().unwrap();
        assert!(!r.completion.is_empty());
    }
    let report = coord.metrics.snapshot();
    assert_eq!(report.requests, 3);
    assert!(report.mean_alpha.is_finite());
    coord.shutdown();
}
