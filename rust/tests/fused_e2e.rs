//! Fused-execution end-to-end tests over the real AOT artifacts (skipped
//! when `make artifacts` hasn't run):
//!
//! * lane equivalence — `forward_batch` row *i* is **bit-identical** to a
//!   single `forward` of the same sequence (the property that makes fused
//!   scheduling invisible to greedy decoding);
//! * the fused scheduler produces byte-identical greedy token streams to
//!   per-session stepping while issuing measurably fewer engine
//!   dispatches per committed token;
//! * the coordinator's fused serving path matches `max_inflight = 1`;
//! * the quarantined lockstep reference (`legacy_lockstep`) charges the
//!   executed batch size.

use specedge::config::{DecisionMode, ExecMode, KernelPath, KvCacheMode, RunConfig, TreeChoice};
use specedge::coordinator::fuser::{self, TickEvent};
use specedge::costmodel::TreeShape;
use specedge::coordinator::{legacy_lockstep, Coordinator};
use specedge::hetero::{LatencyModel, Mapping, Platform};
use specedge::models::VariantKey;
use specedge::runtime::Engine;
use specedge::spec::{AcceptRule, DecodeSession, DecoderSetup};
use specedge::tokenizer::{Tokenizer, SEP_ID};
use specedge::util::rng::Rng;
use specedge::workload::Request;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn engine() -> Option<Engine> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(dir).expect("engine"))
}

fn setup(gamma: usize, max_new: usize, kernel: KernelPath) -> DecoderSetup {
    DecoderSetup {
        drafter: VariantKey::parse("drafter_fp").unwrap(),
        target: VariantKey::parse("target_w8a8").unwrap(),
        kernel,
        mapping: Mapping::heterogeneous(1),
        gamma,
        rule: AcceptRule::Greedy,
        exec: ExecMode::Modular,
        max_new,
    }
}

/// Distinct translate prompts from the eval set (cycled past its length).
fn prompts(engine: &Engine, n: usize) -> Vec<Vec<u32>> {
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec).unwrap();
    let samples: Vec<_> = engine
        .manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .collect();
    assert!(!samples.is_empty(), "eval set has no translate samples");
    (0..n)
        .map(|i| {
            let s = samples[i % samples.len()];
            let mut ids = tokenizer.encode(&s.prompt, true).unwrap();
            ids.push(SEP_ID);
            ids
        })
        .collect()
}

// ---- lane equivalence ---------------------------------------------------

#[test]
fn prop_forward_batch_lanes_bit_identical_to_single_forward() {
    let Some(engine) = engine() else { return };
    let Some(&bb) = engine
        .manifest
        .batch_sizes
        .iter()
        .find(|&&b| b > 1)
    else {
        eprintln!("SKIP: no batched artifact sizes in manifest");
        return;
    };
    let mut rng = Rng::new(0xFACE);
    for case in 0..6u32 {
        for key in ["drafter_fp", "target_w8a8"] {
            let v = VariantKey::parse(key).unwrap();
            for &bucket in engine.manifest.seq_buckets.iter().take(2) {
                // bb random sequences of random lengths and contents.
                let seqs: Vec<Vec<u32>> = (0..bb)
                    .map(|_| {
                        let len = 2 + rng.below(bucket - 2);
                        (0..len).map(|_| 4 + rng.below(40) as u32).collect()
                    })
                    .collect();
                let views: Vec<&[u32]> = seqs.iter().map(|s| s.as_slice()).collect();
                let batch = engine
                    .forward_batch(v, KernelPath::Ref, &views, bucket)
                    .unwrap();
                for (bi, s) in seqs.iter().enumerate() {
                    let single = engine.forward(v, KernelPath::Ref, s, bucket).unwrap();
                    for pos in 0..s.len() {
                        assert_eq!(
                            batch.row(bi, pos),
                            single.row(0, pos),
                            "case {case} {key} bucket {bucket} lane {bi} pos {pos}: \
                             batched row not bit-identical to single forward"
                        );
                    }
                }
            }
        }
    }
}

// ---- fused scheduler vs per-session stepping ----------------------------

#[test]
fn fused_scheduler_matches_stepping_with_fewer_dispatches_per_token() {
    let Some(engine) = engine() else { return };
    let lat = LatencyModel::new(Platform::imx95());
    let n = 4; // ≥ 4 concurrent speculative sessions (acceptance criterion)
    let ps = prompts(&engine, n);
    let mk = || setup(3, 16, KernelPath::Ref);

    // Reference: per-session run-to-completion stepping (each planned
    // engine call its own dispatch).
    let calls0 = engine.n_forward_calls.get();
    let mut stepped_tokens = Vec::new();
    for p in &ps {
        let mut s = DecodeSession::new(&engine, lat.clone(), mk(), true, p);
        while !s.is_done() {
            s.step(&engine).unwrap();
        }
        stepped_tokens.push(s.into_outcome().tokens);
    }
    let stepped_calls = engine.n_forward_calls.get() - calls0;

    // Fused: all sessions tick together through the shared executor.
    let mut sessions: Vec<DecodeSession> = ps
        .iter()
        .map(|p| DecodeSession::new(&engine, lat.clone(), mk(), true, p))
        .collect();
    let calls1 = engine.n_forward_calls.get();
    let mut fused_shared = 0usize;
    let mut ticks = 0usize;
    loop {
        let mut refs: Vec<&mut DecodeSession> = sessions
            .iter_mut()
            .filter(|s| !s.is_done())
            .collect();
        if refs.is_empty() {
            break;
        }
        let (events, stats) = fuser::tick(&engine, &lat, &mut refs, None, false);
        assert!(
            !events.iter().any(|e| matches!(e, TickEvent::Failed)),
            "no session may fail"
        );
        fused_shared += stats.fused_dispatches;
        assert!(stats.lanes_executed >= stats.lanes_real);
        ticks += 1;
        assert!(ticks < 10_000, "scheduler failed to converge");
    }
    let fused_calls = engine.n_forward_calls.get() - calls1;
    let fused_tokens: Vec<Vec<u32>> = sessions
        .into_iter()
        .map(|s| s.into_outcome().tokens)
        .collect();

    // Byte-identical greedy token streams.
    assert_eq!(fused_tokens, stepped_tokens, "fusion changed token streams");
    let toks: usize = fused_tokens.iter().map(Vec::len).sum();
    assert!(toks > 0);

    // Measurably fewer engine dispatches per committed token.
    let per_tok_fused = fused_calls as f64 / toks as f64;
    let per_tok_stepped = stepped_calls as f64 / toks as f64;
    assert!(
        per_tok_fused < per_tok_stepped,
        "fused {per_tok_fused:.3} !< stepped {per_tok_stepped:.3} dispatches/token"
    );
    assert!(fused_shared > 0, "expected at least one cross-session fused dispatch");
}

#[test]
fn monolithic_sessions_tick_through_the_singleton_path() {
    let Some(engine) = engine() else { return };
    if engine.manifest.mono(3).is_none() {
        eprintln!("SKIP: no monolithic gamma=3 artifact (fast build)");
        return;
    }
    let lat = LatencyModel::new(Platform::imx95());
    let ps = prompts(&engine, 2);
    let mk = || DecoderSetup { exec: ExecMode::Monolithic, ..setup(3, 12, KernelPath::Pallas) };

    let mut stepped = Vec::new();
    for p in &ps {
        let mut s = DecodeSession::new(&engine, lat.clone(), mk(), true, p);
        while !s.is_done() {
            s.step(&engine).unwrap();
        }
        stepped.push(s.into_outcome().tokens);
    }

    let mut sessions: Vec<DecodeSession> = ps
        .iter()
        .map(|p| DecodeSession::new(&engine, lat.clone(), mk(), true, p))
        .collect();
    loop {
        let mut refs: Vec<&mut DecodeSession> =
            sessions.iter_mut().filter(|s| !s.is_done()).collect();
        if refs.is_empty() {
            break;
        }
        let (events, stats) = fuser::tick(&engine, &lat, &mut refs, None, false);
        assert!(!events.iter().any(|e| matches!(e, TickEvent::Failed)));
        // Mono spec-steps are never cross-fused.
        assert_eq!(stats.fused_dispatches, 0);
        assert_eq!(stats.lanes_real, stats.lanes_executed);
    }
    let ticked: Vec<Vec<u32>> =
        sessions.into_iter().map(|s| s.into_outcome().tokens).collect();
    assert_eq!(ticked, stepped);
}

// ---- coordinator-level parity -------------------------------------------

fn coord_cfg(max_inflight: usize) -> RunConfig {
    RunConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        max_new_tokens: 12,
        gamma: Some(3),
        kernel_path: KernelPath::Ref, // the lowering with batched artifacts
        max_inflight,
        ..RunConfig::default()
    }
}

fn run_coord(max_inflight: usize, n: usize) -> (Vec<Vec<u32>>, specedge::metrics::Report) {
    run_coord_with(coord_cfg(max_inflight), n)
}

fn run_coord_with(cfg: RunConfig, n: usize) -> (Vec<Vec<u32>>, specedge::metrics::Report) {
    let coord = Arc::new(Coordinator::start(cfg, Platform::imx95()).unwrap());
    let manifest = specedge::runtime::Manifest::load(Path::new("artifacts")).unwrap();
    let tokenizer = Tokenizer::from_manifest(&manifest.tokenizer_spec).unwrap();
    let samples: Vec<_> = manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .collect();
    let handles: Vec<_> = (0..n)
        .map(|i| {
            let s = samples[i % samples.len()];
            let mut prompt = tokenizer.encode(&s.prompt, true).unwrap();
            prompt.push(SEP_ID);
            coord.submit(Request {
                id: i as u64,
                task: "translate".into(),
                prompt,
                truth: String::new(),
                arrival_s: 0.0,
                class: None,
            })
        })
        .collect();
    let mut outs: Vec<_> = handles.into_iter().map(|h| h.wait().unwrap()).collect();
    outs.sort_by_key(|o| o.id);
    let report = coord.metrics.snapshot();
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
    (outs.into_iter().map(|o| o.tokens).collect(), report)
}

#[test]
fn coordinator_fused_serving_matches_single_inflight_token_streams() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    let (single, single_report) = run_coord(1, 6);
    let (fused, fused_report) = run_coord(4, 6);
    assert_eq!(fused, single, "fused serving changed token streams");
    assert!(single_report.dispatches > 0 && fused_report.dispatches > 0);
    // With ≥ 4 concurrent speculative requests on a batched-capable
    // kernel, the fused path must actually share dispatches...
    assert!(
        fused_report.fused_dispatches > 0,
        "no shared dispatches at max_inflight=4"
    );
    // ...and issue measurably fewer engine calls for the same tokens.
    assert_eq!(fused_report.tokens_out, single_report.tokens_out);
    assert!(
        fused_report.dispatches < single_report.dispatches,
        "fused {} !< single {}",
        fused_report.dispatches,
        single_report.dispatches
    );
    let fill = fused_report.batch_fill;
    assert!(fill > 0.0 && fill <= 1.0, "batch fill {fill} out of range");
}

// ---- tree speculation parity --------------------------------------------

/// Width-1 trees ARE the chain: for both accept rules, a session handed a
/// `1xD` shape must produce bit-identical tokens and simulated seconds to
/// the plain chain session (the session normalizes branching ≤ 1 away, so
/// this pins that contract end-to-end, RNG draw pattern included).
#[test]
fn tree_width_one_is_bit_identical_to_chain_sessions() {
    let Some(engine) = engine() else { return };
    let lat = LatencyModel::new(Platform::imx95());
    for rule in [AcceptRule::Greedy, AcceptRule::Stochastic] {
        for p in prompts(&engine, 2) {
            let mk = || DecoderSetup { rule, ..setup(3, 12, KernelPath::Ref) };
            let mut chain =
                DecodeSession::new(&engine, lat.clone(), mk(), true, &p).with_rng(Rng::new(7));
            while !chain.is_done() {
                chain.step(&engine).unwrap();
            }
            let chain_out = chain.into_outcome();
            let mut tree =
                DecodeSession::new(&engine, lat.clone(), mk(), true, &p).with_rng(Rng::new(7));
            tree.set_tree(Some(TreeShape::new(1, 3)));
            while !tree.is_done() {
                tree.step(&engine).unwrap();
            }
            let tree_out = tree.into_outcome();
            assert_eq!(tree_out.tokens, chain_out.tokens, "{rule:?}: tokens diverged");
            assert_eq!(
                tree_out.sim_s.to_bits(),
                chain_out.sim_s.to_bits(),
                "{rule:?}: simulated charge diverged"
            );
            assert_eq!(tree_out.tree_rounds, 0, "1-wide shape must not run tree rounds");
        }
    }
}

/// A real (branching ≥ 2) greedy tree decode commits exactly the chain's
/// token stream — both follow the target argmax — while actually running
/// multi-lane tree rounds.
#[test]
fn tree_greedy_decode_matches_chain_stream_with_tree_rounds() {
    let Some(engine) = engine() else { return };
    let lat = LatencyModel::new(Platform::imx95());
    for p in prompts(&engine, 3) {
        let mut chain = DecodeSession::new(&engine, lat.clone(), setup(2, 12, KernelPath::Ref), true, &p);
        while !chain.is_done() {
            chain.step(&engine).unwrap();
        }
        let chain_out = chain.into_outcome();
        let mut tree = DecodeSession::new(&engine, lat.clone(), setup(2, 12, KernelPath::Ref), true, &p);
        tree.set_tree(Some(TreeShape::new(2, 2)));
        while !tree.is_done() {
            tree.step(&engine).unwrap();
        }
        let tree_out = tree.into_outcome();
        assert_eq!(tree_out.tokens, chain_out.tokens, "greedy tree diverged from chain");
        assert!(tree_out.tree_rounds > 0, "no tree rounds ran");
        assert!(tree_out.tree_lanes_real <= tree_out.tree_lanes_executed);
        assert!(tree_out.tree_lanes_executed > 0);
    }
}

/// Coordinator-level chain parity across decision modes: `tree: 1x3`
/// (the chain written as a degenerate tree) serves byte-identical token
/// streams to the default chain configuration under both the analytic
/// and the calibrated decision models, and never runs a tree round.
#[test]
fn tree_width_one_reproduces_chain_serving_across_decision_modes() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    for decision in [DecisionMode::Analytic, DecisionMode::Calibrated] {
        let chain_cfg = RunConfig { decision, ..coord_cfg(4) };
        let tree_cfg = RunConfig {
            decision,
            tree: TreeChoice::Fixed(TreeShape::new(1, 3)),
            ..coord_cfg(4)
        };
        let (chain_tokens, _) = run_coord_with(chain_cfg, 4);
        let (tree_tokens, tree_report) = run_coord_with(tree_cfg, 4);
        assert_eq!(
            tree_tokens, chain_tokens,
            "{decision:?}: 1-wide tree serving diverged from the chain"
        );
        assert_eq!(
            tree_report.tree_rounds, 0,
            "{decision:?}: 1-wide shape must never run tree rounds"
        );
    }
}

// ---- paged KV cache A/B parity ------------------------------------------

/// `kv_cache: on` only changes *pricing*, never decoding: the coordinator
/// serves byte-identical token streams with the cache off (the default —
/// the historical engine) and on, under both decision models, while the
/// cache-on run provably routes admissions through the KV manager and the
/// stock pools never shed.
#[test]
fn kv_cache_on_serves_identical_token_streams_across_decision_modes() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    for decision in [DecisionMode::Analytic, DecisionMode::Calibrated] {
        let off_cfg = RunConfig { decision, ..coord_cfg(4) };
        let on_cfg = RunConfig {
            decision,
            kv_cache: KvCacheMode::On,
            ..coord_cfg(4)
        };
        let (off_tokens, off_report) = run_coord_with(off_cfg, 6);
        let (on_tokens, on_report) = run_coord_with(on_cfg, 6);
        assert_eq!(
            on_tokens, off_tokens,
            "{decision:?}: kv_cache on changed the token streams"
        );
        assert_eq!(
            off_report.kv_lookups, 0,
            "{decision:?}: cache-off run touched the KV manager"
        );
        assert_eq!(on_report.kv_lookups, 6, "{decision:?}: one probe per admission");
        assert_eq!(on_report.kv_memory_shed, 0, "{decision:?}: stock pools shed");
        assert_eq!(on_report.tokens_out, off_report.tokens_out);
        // The gauges saw real occupancy somewhere, within capacity.
        let peak: u64 = on_report.kv_pages_peak.iter().sum();
        assert!(peak > 0, "{decision:?}: no pages ever allocated");
        for pu in 0..2 {
            assert!(on_report.kv_pages_peak[pu] <= on_report.kv_pages_capacity[pu]);
        }
    }
}

/// Same pin with tree speculation live: a branching `2x2` tree fleet
/// decodes the same greedy streams with the cache on as off, and still
/// runs real multi-lane tree rounds.
#[test]
fn kv_cache_on_matches_off_under_tree_speculation() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        return;
    }
    let shape = TreeShape::new(2, 2);
    let off_cfg = RunConfig { tree: TreeChoice::Fixed(shape), ..coord_cfg(4) };
    let on_cfg = RunConfig {
        tree: TreeChoice::Fixed(shape),
        kv_cache: KvCacheMode::On,
        ..coord_cfg(4)
    };
    let (off_tokens, off_report) = run_coord_with(off_cfg, 4);
    let (on_tokens, on_report) = run_coord_with(on_cfg, 4);
    assert_eq!(on_tokens, off_tokens, "kv_cache on diverged under tree speculation");
    assert_eq!(on_report.tree_rounds, off_report.tree_rounds);
    assert!(on_report.tree_rounds > 0, "tree config ran no tree rounds");
    assert_eq!(on_report.kv_lookups, 4);
    assert_eq!(on_report.kv_memory_shed, 0);
}

// ---- lockstep batcher reference accounting ------------------------------

#[test]
fn batched_baseline_charges_executed_batch_size() {
    let Some(engine) = engine() else { return };
    let Some(&exec_b) = engine
        .manifest
        .batch_sizes
        .iter()
        .find(|&&b| b > 1)
    else {
        eprintln!("SKIP: no batched artifact sizes in manifest");
        return;
    };
    let b = exec_b - 1; // partial batch forces padding lanes
    let target = VariantKey::parse("target_w8a8").unwrap();
    let seen = std::cell::RefCell::new(Vec::<usize>::new());
    let sim = |_bucket: usize, lanes: usize| -> f64 {
        seen.borrow_mut().push(lanes);
        0.25
    };
    let outs = legacy_lockstep::batched_baseline(
        &engine,
        target,
        KernelPath::Ref,
        &prompts(&engine, b),
        4,
        &sim,
    )
    .unwrap();
    assert_eq!(outs.len(), b);
    let calls = seen.borrow();
    assert!(!calls.is_empty());
    // The cost closure must be asked for the *executed* lane count ...
    assert!(
        calls.iter().all(|&lanes| lanes == exec_b),
        "charged {calls:?}, executed {exec_b}"
    );
    // ... and the whole executed cost must land on the real requests
    // (conservation: nothing vanishes into the padding lanes).
    let charged: f64 = outs.iter().map(|o| o.sim_s).sum();
    let spent = calls.len() as f64 * 0.25;
    assert!((charged - spent).abs() < 1e-9, "{charged} vs {spent}");
}
