//! Property-based tests (hand-rolled driver — proptest is unavailable
//! offline). Each property runs over a few hundred seeded random cases;
//! failures print the offending seed for reproduction.

use specedge::api::SloClass;
use specedge::costmodel;
use specedge::coordinator::queue::{QueueItem, RequestQueue};
use specedge::hetero::{LatencyModel, Mapping, Platform, PuAssignment, PuId};
use specedge::kvcache::{NodeId, PageAllocator, PageId, PrefixCache};
use specedge::models::{ModelSpec, Role, Scheme};
use specedge::runtime::Manifest;
use specedge::scenario::{
    materialize, ArrivalProcess, ClassMix, RequestClass, ScenarioSpec, WorkloadTrace,
};
use specedge::spec::sampling::{
    greedy_accept_len, stochastic_accept, top1, top_k_into, tree_verify_node, NodeVerdict,
};
use specedge::tokenizer::Tokenizer;
use specedge::util::json::Json;
use specedge::util::rng::Rng;
use specedge::util::stats::Summary;
use specedge::workload::Request;

/// Tiny property-test driver: `cases` seeded runs of `f(rng, case_index)`.
fn forall(name: &str, cases: u64, mut f: impl FnMut(&mut Rng, u64)) {
    for i in 0..cases {
        let seed = 0x9E37 ^ (i * 0x100001b3);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng, i)
        }));
        if let Err(e) = result {
            eprintln!("property {name} failed at case {i} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

fn rand_spec(rng: &mut Rng) -> ModelSpec {
    ModelSpec {
        name: if rng.f64() < 0.5 { "target" } else { "drafter" }.into(),
        n_layers: rng.range(1, 8) as usize,
        d_model: 32 * rng.range(1, 8) as usize,
        n_heads: 4,
        ffn_dim: 32 * rng.range(1, 12) as usize,
        vocab: 48,
        param_count: 100_000,
    }
}

// ---------- cost model properties -------------------------------------

#[test]
fn prop_speedup_positive_and_bounded() {
    forall("speedup bounds", 500, |rng, _| {
        let alpha = rng.f64();
        let c = rng.f64() * 3.0;
        let gamma = rng.range(0, 8) as usize;
        let s = costmodel::speedup(alpha, gamma, c);
        assert!(s.is_finite() && s > 0.0, "S={s} a={alpha} g={gamma} c={c}");
        // Hard upper bound: S <= (γ+1)/(γc+1) (the α→1 limit).
        let ub = (gamma as f64 + 1.0) / (gamma as f64 * c + 1.0) + 1e-9;
        assert!(gamma == 0 || s <= ub, "S={s} > ub={ub}");
    });
}

#[test]
fn prop_no_speedup_when_c_geq_alpha() {
    // Paper §II-B: c < α is necessary for any speedup.
    forall("c >= alpha => S <= 1", 500, |rng, _| {
        let alpha = rng.f64() * 0.99;
        let c = alpha + rng.f64() * 2.0; // c >= alpha
        for gamma in 1..=8 {
            let s = costmodel::speedup(alpha, gamma, c);
            assert!(s <= 1.0 + 1e-9, "S={s} a={alpha} c={c} g={gamma}");
        }
    });
}

#[test]
fn prop_optimal_gamma_is_argmax() {
    forall("optimal gamma argmax", 300, |rng, _| {
        let alpha = rng.f64();
        let c = rng.f64() * 1.5;
        let best = costmodel::optimal_gamma(alpha, c);
        for g in 0..=costmodel::GAMMA_MAX {
            assert!(
                costmodel::speedup(alpha, g, c) <= best.speedup + 1e-12,
                "gamma {g} beats reported optimum"
            );
        }
    });
}

#[test]
fn prop_expected_tokens_monotone_in_alpha() {
    forall("E[tokens] monotone", 200, |rng, _| {
        let gamma = rng.range(1, 8) as usize;
        let a1 = rng.f64() * 0.9;
        let a2 = a1 + rng.f64() * (1.0 - a1);
        assert!(
            costmodel::expected_tokens_per_round(a2, gamma) + 1e-12
                >= costmodel::expected_tokens_per_round(a1, gamma)
        );
    });
}

// ---------- latency model properties -----------------------------------

#[test]
fn prop_latency_positive_monotone_seq() {
    let lat = LatencyModel::new(Platform::imx95());
    forall("latency monotone in seq", 200, |rng, _| {
        let spec = rand_spec(rng);
        let pu = if rng.f64() < 0.5 {
            PuAssignment::Gpu
        } else {
            PuAssignment::Cpu { cores: rng.range(1, 6) as usize }
        };
        let scheme = if rng.f64() < 0.5 { Scheme::Fp } else { Scheme::W8a8 };
        let mut prev = 0.0;
        for s in [8, 16, 32, 64, 128] {
            let t = lat.forward_latency(&spec, scheme, pu, s);
            assert!(t > 0.0 && t.is_finite());
            assert!(t >= prev, "latency decreased with seq_len");
            prev = t;
        }
    });
}

#[test]
fn prop_cost_coefficient_scale_invariant() {
    // c must not depend on absolute CPU peak (ratio property) for
    // homogeneous mappings of the same scheme.
    forall("c scale invariance", 100, |rng, _| {
        let mut p1 = Platform::imx95();
        let scale = 0.5 + rng.f64() * 4.0;
        p1.cpu.dispatch_overhead_s = 0.0; // overhead is not scale-free
        let mut p2 = p1.clone();
        p2.cpu.peak_gflops_per_core *= scale;
        let l1 = LatencyModel::new(p1);
        let l2 = LatencyModel::new(p2);
        let d = ModelSpec {
            name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
            ffn_dim: 256, vocab: 48, param_count: 0,
        };
        let t = ModelSpec {
            name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
            ffn_dim: 352, vocab: 48, param_count: 0,
        };
        let cores = rng.range(1, 6) as usize;
        let m = Mapping::homogeneous(cores);
        let c1 = l1.cost_coefficient((&d, Scheme::Fp), (&t, Scheme::Fp), m, 63);
        let c2 = l2.cost_coefficient((&d, Scheme::Fp), (&t, Scheme::Fp), m, 63);
        assert!((c1 - c2).abs() < 1e-9, "{c1} vs {c2}");
    });
}

// ---------- sampling properties -----------------------------------------

#[test]
fn prop_greedy_accept_len_is_longest_prefix() {
    forall("greedy prefix", 300, |rng, _| {
        let n = rng.range(0, 8) as usize;
        let drafted: Vec<u32> = (0..n).map(|_| rng.below(4) as u32).collect();
        let target: Vec<u32> = (0..n + 1).map(|_| rng.below(4) as u32).collect();
        let k = greedy_accept_len(&drafted, &target);
        assert!(k <= n);
        for i in 0..k {
            assert_eq!(drafted[i], target[i]);
        }
        if k < n {
            assert_ne!(drafted[k], target[k]);
        }
    });
}

#[test]
fn prop_stochastic_accept_count_in_range() {
    forall("stochastic range", 200, |rng, _| {
        let gamma = rng.range(1, 6) as usize;
        let vocab = 8;
        let mut mk_dist = |rng: &mut Rng| {
            let mut v: Vec<f32> = (0..vocab).map(|_| rng.f64() as f32 + 0.01).collect();
            let z: f32 = v.iter().sum();
            v.iter_mut().for_each(|x| *x /= z);
            v
        };
        let drafted: Vec<u32> = (0..gamma).map(|_| rng.below(vocab) as u32).collect();
        let dp: Vec<Vec<f32>> = (0..gamma).map(|_| mk_dist(rng)).collect();
        let tp: Vec<Vec<f32>> = (0..=gamma).map(|_| mk_dist(rng)).collect();
        let out = stochastic_accept(&drafted, &dp, &tp, rng);
        assert!(out.n_accepted <= gamma);
        assert!((out.correction as usize) < vocab);
    });
}

#[test]
fn prop_top_k_matches_full_sort() {
    // Partial top-k must equal the full stable sort truncated to k:
    // descending score, earlier index first on ties, out[0] == top1.
    forall("top-k vs full sort", 300, |rng, _| {
        let n = 1 + rng.below(64);
        // Quantized scores force heavy ties; a few exact duplicates more.
        let p: Vec<f32> = (0..n).map(|_| (rng.below(8) as f32) / 8.0).collect();
        let mut reference: Vec<u32> = (0..n as u32).collect();
        reference.sort_by(|&a, &b| {
            p[b as usize].partial_cmp(&p[a as usize]).unwrap().then(a.cmp(&b))
        });
        let mut out = Vec::new();
        for k in 0..=6usize {
            top_k_into(&p, k, &mut out);
            assert_eq!(out, &reference[..k.min(n)], "k={k} p={p:?}");
            if k >= 1 {
                assert_eq!(out[0], top1(&p));
            }
        }
    });
}

#[test]
fn prop_tree_verify_node_width_one_is_the_chain_rule() {
    // With one child, the per-node residual rule degenerates to the chain
    // accept rule: accept iff u < min(1, t(x)/q(x)) with the same single
    // uniform draw.
    forall("tree node width-1 chain rule", 300, |rng, _| {
        let vocab = 8;
        let mut mk_dist = |rng: &mut Rng| {
            let mut v: Vec<f32> = (0..vocab).map(|_| rng.f64() as f32 + 0.01).collect();
            let z: f32 = v.iter().sum();
            v.iter_mut().for_each(|x| *x /= z);
            v
        };
        let q = mk_dist(rng);
        let t = mk_dist(rng);
        let x = rng.below(vocab);
        let accept_p = (t[x].max(0.0) / q[x].max(1e-30)).min(1.0);
        let mut probe = rng.clone();
        let u = probe.f64();
        let verdict = tree_verify_node(&[x as u32], &q, &t, rng);
        if u < accept_p as f64 {
            assert_eq!(verdict, NodeVerdict::Accepted(0), "u={u} p={accept_p}");
        } else {
            let NodeVerdict::Rejected(corr) = verdict else {
                panic!("u={u} >= p={accept_p} but the node accepted");
            };
            // The correction must come from the positive residual t − q
            // (unless it is empty everywhere and the rule falls back).
            let resid_ok = (t[corr as usize] - q[corr as usize]) > 0.0
                || t.iter().zip(&q).all(|(a, b)| a - b <= 0.0);
            assert!(resid_ok, "correction {corr} has no residual mass");
        }
    });
}

#[test]
fn prop_tree_tokens_collapse_to_chain_at_width_one() {
    forall("tree tokens width-1 chain", 300, |rng, _| {
        let alpha = rng.f64() * 0.999;
        let depth = 1 + rng.below(8);
        let chain = costmodel::expected_tokens_per_round(alpha, depth);
        let tree = costmodel::expected_tree_tokens_per_round(alpha, 1, depth);
        assert!((chain - tree).abs() < 1e-12, "a={alpha} d={depth}: {chain} vs {tree}");
        // Widening strictly helps expected tokens (never the chain's cost).
        let wider = costmodel::expected_tree_tokens_per_round(alpha, 3, depth);
        assert!(wider + 1e-12 >= tree);
        assert!(tree >= 1.0 && tree <= 1.0 + depth as f64 + 1e-12);
    });
}

// ---------- substrate properties ----------------------------------------

#[test]
fn prop_json_roundtrip_random_values() {
    fn rand_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.f64() * 2e6).round() / 1e3),
            3 => {
                let n = rng.below(8);
                let s: String = (0..n)
                    .map(|_| (b'a' + rng.below(26) as u8) as char)
                    .collect();
                Json::Str(format!("{s}\"\\\n✓"))
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| rand_json(rng, depth + 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.below(5) {
                    o.set(&format!("k{i}"), rand_json(rng, depth + 1));
                }
                o
            }
        }
    }
    forall("json roundtrip", 300, |rng, _| {
        let j = rand_json(rng, 0);
        let parsed = Json::parse(&j.to_string()).expect("parse own output");
        assert_eq!(parsed, j);
        let pretty = Json::parse(&j.to_string_pretty()).expect("parse pretty");
        assert_eq!(pretty, j);
    });
}

#[test]
fn prop_tokenizer_roundtrip_random_text() {
    let t = Tokenizer::builtin();
    let alphabet: Vec<char> =
        " abcdefghijklmnopqrstuvwxyz.,?!-0123456789:'".chars().collect();
    forall("tokenizer roundtrip", 300, |rng, _| {
        let n = rng.below(120);
        let text: String = (0..n).map(|_| *rng.choose(&alphabet)).collect();
        let ids = t.encode(&text, true).unwrap();
        assert_eq!(t.decode(&ids), text);
        assert!(ids.iter().all(|&i| (i as usize) < t.vocab_size));
    });
}

#[test]
fn prop_summary_percentiles_ordered() {
    forall("percentiles ordered", 200, |rng, _| {
        let n = 1 + rng.below(200);
        let mut s = Summary::new();
        for _ in 0..n {
            s.push(rng.f64() * 100.0 - 50.0);
        }
        let b = s.box_stats();
        assert!(b.min <= b.q1 && b.q1 <= b.median && b.median <= b.q3 && b.q3 <= b.max);
        assert!(b.min <= b.mean && b.mean <= b.max);
    });
}

#[test]
fn prop_queue_never_exceeds_capacity() {
    forall("queue capacity", 100, |rng, _| {
        let cap = 1 + rng.below(16);
        let q = RequestQueue::new(cap);
        let mut pushed = 0usize;
        for i in 0..40 {
            let (tx, _rx) = std::sync::mpsc::channel();
            let item = QueueItem::new(
                Request {
                    id: i,
                    task: "t".into(),
                    prompt: vec![1],
                    truth: String::new(),
                    arrival_s: 0.0,
                    class: None,
                }
                .into(),
                tx,
                None,
            );
            if q.push(item).is_ok() {
                pushed += 1;
            }
            assert!(q.len() <= cap);
            if rng.f64() < 0.3 && !q.is_empty() {
                q.pop();
                pushed -= 1;
            }
            assert_eq!(q.len(), pushed);
        }
    });
}

#[test]
fn prop_rng_shuffle_uniform_enough() {
    // First element of a 5-shuffle should be ~uniform over the 5 values.
    let mut counts = [0usize; 5];
    let mut rng = Rng::new(42);
    let n = 20_000;
    for _ in 0..n {
        let mut v = [0usize, 1, 2, 3, 4];
        rng.shuffle(&mut v);
        counts[v[0]] += 1;
    }
    for &c in &counts {
        let frac = c as f64 / n as f64;
        assert!((frac - 0.2).abs() < 0.02, "{counts:?}");
    }
}

// ---------- paged KV cache properties -----------------------------------

#[test]
fn prop_page_allocator_conserves_pages() {
    // Under any interleaving of all-or-nothing allocs and releases:
    // used + available == capacity, no page id is handed out twice, a
    // refusal really meant the pool was short, and a drained pool returns
    // to full capacity (double frees stay loud errors, not corruption).
    forall("allocator conservation", 200, |rng, _| {
        let cap = [1 + rng.below(24), rng.below(16)];
        let mut a = PageAllocator::new(cap[0], cap[1]);
        let mut held: [Vec<PageId>; 2] = [Vec::new(), Vec::new()];
        for _ in 0..60 {
            let pu = if rng.f64() < 0.5 { PuId::Cpu } else { PuId::Gpu };
            let i = pu.index();
            if rng.f64() < 0.55 {
                let n = rng.below(5);
                match a.alloc(pu, n) {
                    Some(pages) => {
                        assert_eq!(pages.len(), n);
                        held[i].extend(pages);
                    }
                    None => assert!(
                        a.available(pu) < n,
                        "refused a satisfiable {n}-page request"
                    ),
                }
            } else if !held[i].is_empty() {
                let k = 1 + rng.below(held[i].len());
                let give: Vec<PageId> = held[i].split_off(held[i].len() - k);
                a.release(pu, &give).unwrap();
            }
            for (pu, slot) in [(PuId::Cpu, 0), (PuId::Gpu, 1)] {
                assert_eq!(a.used(pu), held[slot].len());
                assert_eq!(a.used(pu) + a.available(pu), cap[slot]);
                assert!(a.peak(pu) <= cap[slot]);
                let mut ids = held[slot].clone();
                ids.sort_unstable();
                ids.dedup();
                assert_eq!(ids.len(), held[slot].len(), "duplicate page handed out");
            }
        }
        a.release(PuId::Cpu, &held[0]).unwrap();
        a.release(PuId::Gpu, &held[1]).unwrap();
        assert_eq!(a.available(PuId::Cpu), cap[0]);
        assert_eq!(a.available(PuId::Gpu), cap[1]);
        if !held[0].is_empty() {
            assert!(a.release(PuId::Cpu, &held[0][..1]).is_err(), "double free accepted");
            assert_eq!(a.available(PuId::Cpu), cap[0]);
        }
    });
}

#[test]
fn prop_prefix_trie_refcounts_and_page_conservation() {
    // Interleaved admissions (attach + insert of the unmatched tail) and
    // detaches over a 2-symbol alphabet (maximal prefix collisions). At
    // every step: each node's refcount equals the number of live session
    // paths holding it, and every allocated page is owned by exactly one
    // trie node. Draining all sessions and evicting to empty returns every
    // page to the pools.
    forall("trie refcounts + conservation", 100, |rng, _| {
        let chunk = 1 + rng.below(4);
        let mut c = PrefixCache::new(chunk);
        let mut a = PageAllocator::new(256, 256);
        let m = if rng.f64() < 0.5 {
            Mapping::heterogeneous(1)
        } else {
            Mapping::homogeneous(2)
        };
        let mut paths: Vec<Vec<NodeId>> = Vec::new();
        let mut created: Vec<NodeId> = Vec::new();
        for _ in 0..40 {
            if rng.f64() < 0.6 || paths.is_empty() {
                // Admit: match what we can, insert the unmatched remainder.
                let len = chunk * (1 + rng.below(3)) + rng.below(chunk);
                let toks: Vec<u32> = (0..len).map(|_| rng.below(2) as u32).collect();
                let hit = c.attach(&toks, m);
                let mut path = hit.path.clone();
                let mut parent = path.last().copied();
                for ch in toks[hit.tokens..].chunks_exact(chunk) {
                    let d = a.alloc(m.drafter.id(), 1).unwrap()[0];
                    let t = a.alloc(m.target.id(), 1).unwrap()[0];
                    let id = c.insert(parent, ch, m, d, t);
                    created.push(id);
                    path.push(id);
                    parent = Some(id);
                }
                paths.push(path);
            } else {
                let k = rng.below(paths.len());
                let path = paths.swap_remove(k);
                c.detach(&path);
            }
            for pu in [PuId::Cpu, PuId::Gpu] {
                assert_eq!(a.used(pu), c.pages_held(pu), "page leaked or double-owned");
            }
            for &id in &created {
                let expect = paths.iter().filter(|p| p.contains(&id)).count();
                assert_eq!(c.refs(id), expect, "refcount drift on node {id}");
            }
        }
        for p in paths.drain(..) {
            c.detach(&p);
        }
        while c.evict_one(&mut a).unwrap().is_some() {}
        assert!(c.is_empty());
        assert_eq!(a.used(PuId::Cpu), 0);
        assert_eq!(a.used(PuId::Gpu), 0);
    });
}

#[test]
fn prop_cow_copies_iff_shared_and_never_mutates_the_node() {
    // cow_page hands the writer a private copy exactly when the node is
    // shared (refs > 1), and the node's own page pair is never replaced —
    // a later reader through the shared prefix still sees the original.
    forall("cow shared-page safety", 200, |rng, _| {
        let mut c = PrefixCache::new(2);
        let mut a = PageAllocator::new(16, 16);
        let m = Mapping::heterogeneous(1);
        let d = a.alloc(m.drafter.id(), 1).unwrap()[0];
        let t = a.alloc(m.target.id(), 1).unwrap()[0];
        let root = c.insert(None, &[7, 7], m, d, t);
        let extra = rng.below(4);
        let mut paths = Vec::new();
        for _ in 0..extra {
            paths.push(c.attach(&[7, 7], m).path);
        }
        let role = if rng.f64() < 0.5 { Role::Drafter } else { Role::Target };
        let before = c.pages(root);
        let own = match role {
            Role::Drafter => before.0,
            Role::Target => before.1,
        };
        let (page, copied) = c.cow_page(root, role, &mut a).unwrap();
        assert_eq!(copied, extra >= 1, "copied must track sharing (refs {})", 1 + extra);
        assert_eq!(c.pages(root), before, "COW replaced a node page");
        if copied {
            assert_ne!(page, own, "writer got the shared page");
        } else {
            assert_eq!(page, own);
        }
        for p in paths {
            c.detach(&p);
        }
    });
}

#[test]
fn prop_dse_best_is_feasible_and_optimal() {
    let lat = LatencyModel::new(Platform::imx95());
    forall("dse best optimal", 100, |rng, _| {
        let pair = specedge::dse::PairConfig {
            target: ModelSpec {
                name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
                ffn_dim: 352, vocab: 48, param_count: 816_256,
            },
            target_scheme: Scheme::W8a8,
            drafter: ModelSpec {
                name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
                ffn_dim: 256, vocab: 48, param_count: 230_880,
            },
            drafter_scheme: Scheme::Fp,
        };
        let alpha = rng.f64();
        let seq = 8 + rng.below(120);
        let variant = 1 + rng.below(6);
        let d = specedge::dse::explore_variant(&lat, &pair, variant, alpha, seq);
        assert!(d.best.infeasible.is_none());
        assert!(d.best.speedup >= 1.0 - 1e-12);
        for c in &d.all {
            if c.infeasible.is_none() {
                assert!(c.speedup <= d.best.speedup + 1e-12);
            }
        }
    });
}

// ---------- fleet placement properties --------------------------------

#[test]
fn prop_placement_never_picks_a_shedding_device_when_avoidable() {
    // The issue's placement invariant: whenever at least one device's
    // KV-admission probe is feasible, the chosen device's probe must be
    // feasible too — placement never knowingly routes a request onto a
    // device that would immediately shed it.
    let p = Platform::imx95();
    let lat = LatencyModel::new(p.clone());
    let pair = specedge::dse::PairConfig {
        target: ModelSpec {
            name: "target".into(), n_layers: 4, d_model: 128, n_heads: 4,
            ffn_dim: 352, vocab: 48, param_count: 816_256,
        },
        target_scheme: Scheme::W8a8,
        drafter: ModelSpec {
            name: "drafter".into(), n_layers: 2, d_model: 96, n_heads: 4,
            ffn_dim: 256, vocab: 48, param_count: 230_880,
        },
        drafter_scheme: Scheme::Fp,
    };
    let pages = p.memory.kv_pages(PuId::Cpu);
    forall("placement avoids shed", 300, |rng, _| {
        let n = 1 + rng.below(6);
        let mapping = Mapping::heterogeneous(1 + rng.below(6));
        // Random per-device probes: some loads fit, some guarantee a shed.
        let probes: Vec<Option<specedge::dse::KvLoad>> = (0..n)
            .map(|_| match rng.below(3) {
                0 => None,
                1 => Some(specedge::dse::KvLoad {
                    inflight: 1 + rng.below(3),
                    budget_tokens: 32 + rng.below(96),
                }),
                _ => Some(specedge::dse::KvLoad {
                    inflight: pages + 1 + rng.below(8),
                    budget_tokens: 1 << 20,
                }),
            })
            .collect();
        let loads: Vec<(usize, f64, f64)> = (0..n)
            .map(|_| (rng.below(5), rng.f64() * 10.0, 0.05 + 0.9 * rng.f64()))
            .collect();
        let views: Vec<_> = (0..n)
            .map(|i| specedge::fleet::DeviceView {
                platform: &p,
                cost: &lat,
                mapping,
                queue_len: loads[i].0,
                backlog_s: loads[i].1,
                alpha: loads[i].2,
                kv_probe: probes[i],
            })
            .collect();
        let req = specedge::fleet::PlacementRequest {
            pair: &pair,
            seq_len: 8 + rng.below(120),
            max_new: 8 + rng.below(56),
            slo: if rng.f64() < 0.5 { specedge::api::SloClass::Interactive }
                 else { specedge::api::SloClass::Batch },
            deadline_s: if rng.f64() < 0.5 { Some(rng.f64() * 20.0) } else { None },
        };
        let feasible: Vec<bool> = views
            .iter()
            .map(|v| match &v.kv_probe {
                Some(kv) => specedge::dse::kv_feasible(v.platform, &pair, v.mapping, kv),
                None => true,
            })
            .collect();
        let got = specedge::fleet::place(&views, &req);
        assert!(got.device < n);
        assert_eq!(got.scores.len(), n);
        assert!(got.score.is_finite());
        if feasible.iter().any(|&f| f) {
            assert!(
                feasible[got.device],
                "placed on a shedding device {} (feasible map {feasible:?})",
                got.device
            );
        }
        // The winner is the argmin of the reported scores, lowest index first.
        let best = got
            .scores
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(got.device, best);
    });
}

// ---------- scenario trace properties ---------------------------------

/// Manifest whose eval set covers every task in every class pool, so a
/// generated trace can always be materialized regardless of which tasks
/// the mix's classes draw.
fn all_task_manifest() -> Manifest {
    let mut samples = String::new();
    for class in RequestClass::all() {
        for task in class.task_pool() {
            for (k, body) in ["abc def", "gh ij kl"].iter().enumerate() {
                samples.push_str(&format!(
                    r#"{{"task":"{task}","prompt":"{task} {k}: {body}","completion":"ok"}},"#
                ));
            }
        }
    }
    samples.pop(); // trailing comma
    let j = specedge::util::json::Json::parse(&format!(
        r#"{{
      "tokenizer": {{"specials":["<pad>","<bos>","<eos>","="],
                    "chars":" abcdefghijklmnopqrstuvwxyz.,?!-0123456789:'",
                    "vocab_size":48}},
      "seq_buckets": [128], "batch_sizes": [1],
      "models": {{
        "target": {{"name":"target","n_layers":4,"d_model":128,"n_heads":4,
                   "ffn_dim":352,"vocab":48,"param_count":816256}},
        "drafter": {{"name":"drafter","n_layers":2,"d_model":96,"n_heads":4,
                    "ffn_dim":256,"vocab":48,"param_count":230880}}
      }},
      "variants": {{
        "drafter_fp": {{"role":"drafter","scheme":"fp","model":"drafter",
          "weights":"w_dfp.bin","tensors":[],"artifacts":[]}},
        "target_w8a8": {{"role":"target","scheme":"w8a8","model":"target",
          "weights":"w_tq.bin","tensors":[],"artifacts":[]}}
      }},
      "monolithic": [],
      "eval_samples": [{samples}]}}"#
    ))
    .unwrap();
    Manifest::from_json(std::path::Path::new("/tmp"), &j).unwrap()
}

/// A randomized scenario spec: 1-4 distinct classes, random weights, α
/// regimes, output-length bounds, SLOs and arrival process.
fn rand_scenario(rng: &mut Rng, i: u64) -> ScenarioSpec {
    let mut classes = RequestClass::all().to_vec();
    rng.shuffle(&mut classes);
    let n = 1 + rng.below(classes.len());
    let mix = classes[..n]
        .iter()
        .map(|&class| {
            let lo = 2 + rng.below(8);
            ClassMix {
                class,
                weight: 0.1 + rng.f64(),
                alpha: 0.2 + 0.7 * rng.f64(),
                max_new: (lo, lo + rng.below(12)),
                slo: if rng.f64() < 0.5 { SloClass::Interactive } else { SloClass::Batch },
                deadline_s: if rng.f64() < 0.3 { Some(0.05 + rng.f64()) } else { None },
            }
        })
        .collect();
    let arrivals = match rng.below(3) {
        0 => ArrivalProcess::Poisson { rate: 1.0 + rng.f64() * 20.0 },
        1 => ArrivalProcess::Bursty {
            base_rate: 1.0 + rng.f64() * 4.0,
            burst_rate: 10.0 + rng.f64() * 30.0,
            period_s: 2.0 + rng.f64() * 20.0,
            burst_frac: 0.1 + rng.f64() * 0.6,
        },
        _ => ArrivalProcess::Diurnal {
            base_rate: 2.0 + rng.f64() * 10.0,
            amplitude: rng.f64() * 0.9,
            period_s: 10.0 + rng.f64() * 60.0,
        },
    };
    ScenarioSpec {
        name: format!("prop_{i}"),
        seed: rng.next_u64(),
        requests: 4 + rng.below(40),
        arrivals,
        mix,
    }
}

#[test]
fn prop_scenario_generation_is_seed_deterministic() {
    // Same spec (including seed) ⇒ byte-identical trace; a different
    // seed moves the trace (the first arrival gap is an f64 exponential
    // draw, so a cross-seed collision over the whole trace is ~2^-52).
    forall("scenario generation deterministic", 150, |rng, i| {
        let spec = rand_scenario(rng, i);
        let a = spec.generate();
        let b = spec.generate();
        assert_eq!(a, b);
        assert_eq!(a.to_jsonl(), b.to_jsonl());
        assert_eq!(a.entries.len(), spec.requests);
        let other = ScenarioSpec { seed: spec.seed ^ 1, ..spec.clone() }.generate();
        assert_ne!(a, other, "seed {} and {} collided", spec.seed, spec.seed ^ 1);
        // Every entry's class is one of the mix's, with a task from its pool.
        for e in &a.entries {
            assert!(spec.mix.iter().any(|m| m.class == e.class));
            assert_eq!(RequestClass::for_task(&e.task), Some(e.class));
        }
    });
}

#[test]
fn prop_trace_save_load_replays_identically() {
    // The replay contract: save → load is the identity on traces, the
    // serialization is a fixed point, and materializing the reloaded
    // trace yields bit-identical prompts/arrivals to the original.
    let m = all_task_manifest();
    let tok = Tokenizer::builtin();
    forall("trace save/load replay", 60, |rng, i| {
        let trace = rand_scenario(rng, i).generate();
        let path = std::env::temp_dir()
            .join(format!("specedge_prop_trace_{}_{i}.jsonl", std::process::id()));
        trace.save(&path).unwrap();
        let loaded = WorkloadTrace::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded, trace);
        assert_eq!(loaded.to_jsonl(), trace.to_jsonl());
        let w1 = materialize(&trace, &m, &tok).unwrap();
        let w2 = materialize(&loaded, &m, &tok).unwrap();
        assert_eq!(w1.requests.len(), w2.requests.len());
        for (a, b) in w1.requests.iter().zip(&w2.requests) {
            assert_eq!(a.prompt, b.prompt, "replay tokens drifted");
            assert_eq!(a.task, b.task);
            assert_eq!(a.class, b.class);
            assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
        }
    });
}
