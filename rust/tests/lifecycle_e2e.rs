//! Request-lifecycle API v2 end-to-end tests: cancellation frees the
//! scheduler slot, deadlines abort with partial output, stop sequences
//! truncate exactly, priority ordering jumps the queue, and the v1 wire
//! protocol stays byte-compatible with the seed server (skipped when
//! `make artifacts` hasn't run).

use specedge::api::{FinishReason, GenOptions, GenerationRequest};
use specedge::config::RunConfig;
use specedge::coordinator::Coordinator;
use specedge::hetero::Platform;
use specedge::server::{Client, Server};
use specedge::tokenizer::Tokenizer;
use specedge::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn have_artifacts() -> bool {
    if Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        false
    }
}

/// γ=1 keeps rounds small (1–2 tokens each), so mid-request lifecycle
/// events have many round boundaries to land on.
fn cfg() -> RunConfig {
    RunConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        max_new_tokens: 64,
        gamma: Some(1),
        max_inflight: 1,
        ..RunConfig::default()
    }
}

/// A real eval-set prompt with a ~57-token reference completion, so γ=1
/// decodes span dozens of round boundaries for lifecycle events to land
/// on.
const LONG_PROMPT: &str = "tr: mogdi mogdi peni ture buda ture hevboco curih ture milori";

fn prompt(text: &str) -> Vec<u32> {
    let t = Tokenizer::builtin();
    let mut p = t.encode(text, true).unwrap();
    p.push(specedge::tokenizer::SEP_ID);
    p
}

fn request(id: u64, options: GenOptions) -> GenerationRequest {
    GenerationRequest::new(id, "translate", prompt(LONG_PROMPT)).with_options(options)
}

#[test]
fn mid_stream_cancel_frees_the_slot() {
    if !have_artifacts() {
        return;
    }
    // Reference run: blocker decodes to completion while a co-scheduled
    // request waits for the (single) slot.
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let blocker = coord.submit(request(1, GenOptions::default()));
    let waiter = coord.submit(request(2, GenOptions::default()));
    let full = blocker.wait().unwrap();
    let waiter_full = waiter.wait().unwrap();
    coord.shutdown();
    assert!(
        full.rounds >= 4,
        "precondition: the blocker must decode over several rounds, got {}",
        full.rounds
    );

    // Cancel run: same pair, but the blocker is cancelled after its
    // first streamed frame — it must abort at a round boundary with the
    // tokens committed so far, and the waiter must reach the slot sooner.
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let blocker = coord.submit(request(1, GenOptions::default()));
    let waiter = coord.submit(request(2, GenOptions::default()));
    let first = blocker.frames().next().expect("first frame");
    assert!(!first.done, "a multi-round decode must not finish in one frame");
    blocker.cancel();
    let cancelled = blocker.wait().unwrap();
    let waiter_cancel = waiter.wait().unwrap();
    let report = coord.metrics.snapshot();
    coord.shutdown();

    assert_eq!(cancelled.finish, FinishReason::Cancelled, "{cancelled:?}");
    assert!(
        cancelled.rounds >= 1 && cancelled.rounds < full.rounds,
        "cancel must abort mid-decode: {} vs full {}",
        cancelled.rounds,
        full.rounds
    );
    assert!(
        cancelled.tokens.len() < full.tokens.len(),
        "cancelled response must carry partial output"
    );
    // Partial output is a prefix of the full (greedy) stream.
    assert_eq!(cancelled.tokens[..], full.tokens[..cancelled.tokens.len()]);
    // The freed slot admits the co-scheduled request earlier: its
    // makespan (queue wait, real clock) improves.
    assert_eq!(waiter_cancel.tokens, waiter_full.tokens);
    assert!(
        waiter_cancel.queue_s < waiter_full.queue_s,
        "cancel must free the slot sooner: {} !< {}",
        waiter_cancel.queue_s,
        waiter_full.queue_s
    );
    assert_eq!(report.finish_count(FinishReason::Cancelled), 1);
}

#[test]
fn cancel_in_queue_sheds_without_decoding() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let blocker = coord.submit(request(1, GenOptions::default()));
    let doomed = coord.submit(request(2, GenOptions::default()));
    doomed.cancel();
    let r = doomed.wait().unwrap();
    assert_eq!(r.finish, FinishReason::Cancelled);
    assert!(r.tokens.is_empty() && r.rounds == 0);
    // The queue-cancelled request also terminates its frame stream.
    assert!(doomed.frames().all(|f| f.done));
    blocker.wait().unwrap();
    // Coordinator-level cancel by id: unknown ids report false.
    assert!(!coord.cancel(999));
    coord.shutdown();
}

#[test]
fn deadline_expiry_returns_partial_tokens() {
    if !have_artifacts() {
        return;
    }
    // Reference: unconstrained decode (sim seconds are deterministic).
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let full = coord.submit(request(1, GenOptions::default())).wait().unwrap();
    coord.shutdown();
    assert!(full.rounds >= 3, "precondition: multi-round decode");
    assert!(full.sim_s > 0.0);

    // Budget half the simulated decode: the session must abort at a
    // round boundary partway through.
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let opts = GenOptions { deadline_s: Some(full.sim_s / 2.0), ..GenOptions::default() };
    let r = coord.submit(request(1, opts)).wait().unwrap();
    let report = coord.metrics.snapshot();
    coord.shutdown();
    assert_eq!(r.finish, FinishReason::DeadlineExceeded, "{r:?}");
    assert!(
        !r.tokens.is_empty() && r.tokens.len() < full.tokens.len(),
        "deadline abort must return partial output: {} of {}",
        r.tokens.len(),
        full.tokens.len()
    );
    assert_eq!(r.tokens[..], full.tokens[..r.tokens.len()]);
    assert_eq!(report.deadline_requests, 1);
    assert_eq!(report.deadline_missed, 1);
    assert!((report.deadline_miss_rate() - 1.0).abs() < 1e-12);
    assert_eq!(report.finish_count(FinishReason::DeadlineExceeded), 1);
}

#[test]
fn expired_deadline_is_shed_at_admission() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let opts = GenOptions { deadline_s: Some(0.0), ..GenOptions::default() };
    let r = coord.submit(request(1, opts)).wait().unwrap();
    let report = coord.metrics.snapshot();
    coord.shutdown();
    assert_eq!(r.finish, FinishReason::DeadlineExceeded);
    assert!(r.tokens.is_empty() && r.rounds == 0, "{r:?}");
    // Shed before decode: no latency-population pollution, but the
    // lifecycle counters move.
    assert_eq!(report.requests, 0);
    assert_eq!(report.deadline_missed, 1);
}

#[test]
fn stop_sequence_truncation_is_exact_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let tok = Tokenizer::builtin();
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let full = coord.submit(request(1, GenOptions::default())).wait().unwrap();
    assert!(
        full.completion.len() >= 4,
        "precondition: completion long enough to cut, got {:?}",
        full.completion
    );
    // Pick a mid-completion substring as the stop sequence; greedy
    // decoding reproduces the same stream, so the output must be the
    // full completion truncated exactly at that substring's first
    // occurrence.
    let stop = full.completion[2..4].to_string();
    let expected = &full.completion[..full.completion.find(&stop).unwrap()];
    let opts = GenOptions { stop_sequences: vec![stop.clone()], ..GenOptions::default() };
    let handle = coord.submit(request(2, opts));
    // Drain the stream too: the worker's stop-length hold-back must keep
    // frames truncation-exact (no token a later match removes is ever
    // streamed).
    let mut streamed: Vec<u32> = Vec::new();
    for f in handle.frames() {
        streamed.extend(&f.tokens);
    }
    let r = handle.wait().unwrap();
    coord.shutdown();
    assert_eq!(r.finish, FinishReason::StopSequence, "{r:?}");
    assert_eq!(r.completion, expected, "stop {stop:?} of {:?}", full.completion);
    // Token-level: a prefix of the full stream, stop tokens excluded.
    assert_eq!(r.tokens[..], full.tokens[..r.tokens.len()]);
    assert_eq!(tok.decode(&r.tokens), expected);
    assert_eq!(streamed, r.tokens, "streamed frames must reassemble the truncated final");
}

#[test]
fn priority_jumps_earlier_low_priority_arrivals() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    // Occupy the single slot so everything below truly queues.
    let blocker = coord.submit(request(1, GenOptions::default()));
    let lows: Vec<_> = (10..13)
        .map(|i| {
            coord.submit(request(i, GenOptions { priority: -5, ..GenOptions::default() }))
        })
        .collect();
    // Submitted last, admitted first among the queued set.
    let high = coord.submit(request(2, GenOptions { priority: 5, ..GenOptions::default() }));
    blocker.wait().unwrap();
    let high_r = high.wait().unwrap();
    let low_rs: Vec<_> = lows.into_iter().map(|h| h.wait().unwrap()).collect();
    coord.shutdown();
    // Single worker, max_inflight 1: admission order == completion
    // order, and queue_s measures time-to-admission. The high-priority
    // request, despite arriving after every low one, waited less than
    // all of them.
    for low in &low_rs {
        assert!(
            high_r.queue_s < low.queue_s,
            "priority inversion: high waited {} vs low {}",
            high_r.queue_s,
            low.queue_s
        );
        assert!(!low.tokens.is_empty(), "low-priority work must not starve");
    }
}

#[test]
fn kv_pages_shed_under_pressure_and_are_reusable_after_cancel() {
    if !have_artifacts() {
        return;
    }
    use specedge::config::KvCacheMode;
    use specedge::models::VariantKey;

    let kv_cfg = || RunConfig {
        kv_cache: KvCacheMode::On,
        max_inflight: 2,
        ..cfg()
    };
    // Same token count as LONG_PROMPT (char-for-char swaps in the first
    // chunk), so every request reserves the identical page budget while
    // sharing no prefix.
    let p1 = prompt(LONG_PROMPT);
    let p2 = prompt("tr: nugat nugat peni ture buda ture hevboco curih ture milori");
    let p3 = prompt("tr: bilop bilop peni ture buda ture hevboco curih ture milori");
    assert_eq!(p1.len(), p2.len());
    assert_eq!(p1.len(), p3.len());

    // Discover the mapping admissions receive, then size the page pools
    // to hold exactly one session's reservation under it.
    let probe = Coordinator::start(kv_cfg(), Platform::imx95()).unwrap();
    let mapping = probe.policy.current_mapping();
    probe.shutdown();

    let engine = specedge::runtime::Engine::load(Path::new("artifacts")).unwrap();
    let d_key = VariantKey::parse("drafter_fp").unwrap();
    let t_key = VariantKey::parse("target_w8a8").unwrap();
    let d_spec = engine.manifest.model_for(d_key).unwrap().clone();
    let t_spec = engine.manifest.model_for(t_key).unwrap().clone();
    let mut platform = Platform::imx95();
    let layout = specedge::kvcache::KvManager::new(
        &platform.memory,
        (&d_spec, d_key.scheme),
        (&t_spec, t_key.scheme),
    )
    .layout();
    let need = layout.chunks(p1.len() + kv_cfg().max_new_tokens);
    let mut demand = [0usize; 2];
    demand[mapping.drafter.id().index()] += need;
    demand[mapping.target.id().index()] += need;
    platform.memory.kv_pages_cpu = demand[0];
    platform.memory.kv_pages_gpu = demand[1];

    let coord = Coordinator::start(kv_cfg(), platform).unwrap();
    let metrics = Arc::clone(&coord.metrics);

    // The blocker takes the whole pool; wait for its first frame so it is
    // provably admitted and mid-decode.
    let blocker =
        coord.submit(GenerationRequest::new(1, "translate", p1).with_options(GenOptions::default()));
    let first = blocker.frames().next().expect("first frame");
    assert!(!first.done);

    // Second session: no free pages, the blocker's nodes are referenced
    // (unevictable), so admission must shed with a typed rejection.
    let starved =
        coord.submit(GenerationRequest::new(2, "translate", p2).with_options(GenOptions::default()));
    let r2 = starved.wait().unwrap();
    assert_eq!(r2.finish, FinishReason::Rejected, "{r2:?}");
    assert!(r2.tokens.is_empty() && r2.rounds == 0, "{r2:?}");

    // Cancel the blocker: the reap must release its pages immediately
    // (private pages AND its now-unreferenced prefix nodes).
    blocker.cancel();
    let r1 = blocker.wait().unwrap();
    assert_eq!(r1.finish, FinishReason::Cancelled);

    // The freed pool admits a fresh session that decodes to completion.
    let third =
        coord.submit(GenerationRequest::new(3, "translate", p3).with_options(GenOptions::default()));
    let r3 = third.wait().unwrap();
    coord.shutdown();
    assert!(
        !r3.tokens.is_empty() && r3.rounds >= 1,
        "post-reap admission must decode normally: {r3:?}"
    );
    assert_ne!(r3.finish, FinishReason::Rejected);

    let report = metrics.snapshot();
    assert_eq!(report.finish_count(FinishReason::Rejected), 1);
    assert!(report.kv_memory_shed >= 1, "shed not counted: {report:?}");
    assert!(
        report.kv_reap_reclaimed_pages > 0,
        "cancel reap reclaimed no pages: {report:?}"
    );
    assert!(report.kv_lookups >= 3);
    // Occupancy gauges stay within the configured pools.
    for pu in 0..2 {
        assert!(report.kv_pages_used[pu] <= report.kv_pages_capacity[pu]);
        assert!(report.kv_pages_peak[pu] <= report.kv_pages_capacity[pu]);
        assert_eq!(report.kv_pages_capacity[pu], demand[pu] as u64);
    }
}

// ---------------------------------------------------------------------
// Wire-protocol tests.
// ---------------------------------------------------------------------

fn start_server(c: RunConfig) -> (Arc<Coordinator>, Server) {
    let coord = Arc::new(Coordinator::start(c, Platform::imx95()).unwrap());
    let server = Server::start(Arc::clone(&coord), Tokenizer::builtin(), 0).unwrap();
    (coord, server)
}

fn stop_server(coord: Arc<Coordinator>, server: Server, client: &mut Client) {
    let mut sd = Json::obj();
    sd.set("cmd", Json::Str("shutdown".into()));
    let _ = client.call(&sd);
    server.stop();
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
}

/// One raw line-level roundtrip (fresh connection, exact reply bytes).
fn raw_roundtrip(port: u16, line: &str) -> String {
    use std::io::{BufRead, BufReader, Write};
    let s = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut w = s.try_clone().unwrap();
    writeln!(w, "{line}").unwrap();
    let mut reply = String::new();
    BufReader::new(s).read_line(&mut reply).unwrap();
    reply.trim_end().to_string()
}

/// v1 wire parity: seed-protocol lines must produce byte-identical
/// replies. Error replies are fully deterministic and pinned
/// byte-for-byte; generate replies carry wall-clock fields, so their
/// *shape* (exact key set — no v2 fields) and deterministic values are
/// pinned instead. Run in isolation by the CI `protocol-compat` step.
#[test]
fn v1_protocol_compat_pinned_replies() {
    if !have_artifacts() {
        return;
    }
    let (coord, server) = start_server(cfg());
    let port = server.port;

    // Seed error replies, byte-for-byte.
    assert_eq!(
        raw_roundtrip(port, "@"),
        r#"{"error":"bad json: json parse error at byte 0: unexpected character","ok":false}"#
    );
    assert_eq!(
        raw_roundtrip(port, r#"{"task":"x"}"#),
        r#"{"error":"missing `prompt`","ok":false}"#
    );
    assert_eq!(
        raw_roundtrip(port, r#"{"cmd":"bogus"}"#),
        r#"{"error":"unknown cmd \"bogus\"","ok":false}"#
    );

    // Seed generate reply: exactly the seed key set (no v2 leakage), in
    // the codec's deterministic (sorted) order.
    let line = format!(r#"{{"prompt":"{LONG_PROMPT}","task":"translate"}}"#);
    let reply = raw_roundtrip(port, &line);
    let j = Json::parse(&reply).unwrap();
    let keys: Vec<&str> = j.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
    assert_eq!(
        keys,
        vec![
            "alpha", "completion", "gamma", "ok", "queue_ms", "real_ms", "rounds",
            "sim_ms", "speculative", "tokens"
        ],
        "v1 reply shape drifted: {reply}"
    );
    assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
    assert!(j.req_f64("sim_ms").unwrap() > 0.0);
    assert!(j.req_usize("rounds").unwrap() > 0);
    // Identical line, identical deterministic fields (sim clock, tokens,
    // completion are reproducible run-to-run).
    let again = Json::parse(&raw_roundtrip(port, &line)).unwrap();
    for k in ["completion", "tokens", "sim_ms", "alpha", "gamma", "speculative"] {
        assert_eq!(j.get(k), again.get(k), "nondeterministic v1 field {k}");
    }

    // Default-option v2 reproduces the v1 stream bit-for-bit, adding
    // only the typed lifecycle fields.
    let v2line =
        format!(r#"{{"v":2,"req_id":7,"prompt":"{LONG_PROMPT}","task":"translate"}}"#);
    let v2 = Json::parse(&raw_roundtrip(port, &v2line)).unwrap();
    assert_eq!(v2.get("completion"), j.get("completion"));
    assert_eq!(v2.get("tokens"), j.get("tokens"));
    assert_eq!(v2.get("sim_ms"), j.get("sim_ms"));
    assert_eq!(v2.get("v"), Some(&Json::Num(2.0)));
    assert_eq!(v2.get("req_id"), Some(&Json::Num(7.0)));
    assert!(v2.get("finish").and_then(Json::as_str).is_some());

    let mut client = Client::connect(port).unwrap();
    stop_server(coord, server, &mut client);
}

/// The same pinned v1 bytes under `serve_mode: threaded`: the two
/// serving shells share every reply-building path, so the wire must be
/// byte-identical regardless of which shell moved the bytes.
#[test]
fn v1_protocol_compat_pinned_replies_threaded_shell() {
    if !have_artifacts() {
        return;
    }
    use specedge::config::ServeMode;
    use specedge::server::{Backend, ServeOptions};

    let coord = Arc::new(Coordinator::start(cfg(), Platform::imx95()).unwrap());
    let opts = ServeOptions { mode: ServeMode::Threaded, ..ServeOptions::default() };
    let server =
        Server::start_opts(Backend::Single(Arc::clone(&coord)), Tokenizer::builtin(), 0, opts)
            .unwrap();
    let port = server.port;

    assert_eq!(
        raw_roundtrip(port, "@"),
        r#"{"error":"bad json: json parse error at byte 0: unexpected character","ok":false}"#
    );
    assert_eq!(
        raw_roundtrip(port, r#"{"task":"x"}"#),
        r#"{"error":"missing `prompt`","ok":false}"#
    );
    assert_eq!(
        raw_roundtrip(port, r#"{"cmd":"bogus"}"#),
        r#"{"error":"unknown cmd \"bogus\"","ok":false}"#
    );
    let line = format!(r#"{{"prompt":"{LONG_PROMPT}","task":"translate"}}"#);
    let j = Json::parse(&raw_roundtrip(port, &line)).unwrap();
    let keys: Vec<&str> = j.as_obj().unwrap().keys().map(|k| k.as_str()).collect();
    assert_eq!(
        keys,
        vec![
            "alpha", "completion", "gamma", "ok", "queue_ms", "real_ms", "rounds",
            "sim_ms", "speculative", "tokens"
        ],
        "threaded-shell v1 reply shape drifted"
    );

    let mut client = Client::connect(port).unwrap();
    stop_server(coord, server, &mut client);
}

#[test]
fn v2_options_and_typed_errors_over_the_wire() {
    if !have_artifacts() {
        return;
    }
    let (coord, server) = start_server(cfg());
    let mut client = Client::connect(server.port).unwrap();
    client
        .set_read_timeout(Some(std::time::Duration::from_secs(60)))
        .unwrap();

    // Baseline full completion for comparison.
    let full = client.generate(LONG_PROMPT, "translate").unwrap();
    let full_tokens = full.req_usize("tokens").unwrap();
    assert!(full_tokens > 2);

    // max_new override truncates and reports Length.
    let opts = GenOptions { max_new: Some(2), ..GenOptions::default() };
    let r = client
        .generate_with(LONG_PROMPT, "translate", 11, &opts)
        .unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
    assert_eq!(r.req_usize("tokens").unwrap(), 2);
    assert_eq!(r.get("finish").and_then(Json::as_str), Some("length"));
    assert_eq!(r.req_usize("req_id").unwrap(), 11);

    // Typed bad_request taxonomy: unknown option, with queue state.
    let mut bad = Json::obj();
    bad.set("v", 2usize.into())
        .set("prompt", Json::Str("tr: a".into()))
        .set("options", Json::parse(r#"{"max_mew":3}"#).unwrap());
    let e = client.call(&bad).unwrap();
    assert_eq!(e.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(e.get("kind").and_then(Json::as_str), Some("bad_request"));
    assert!(e.get("queue_len").is_some() && e.get("queue_capacity").is_some());

    // Cancel command for an unknown id: typed bad_request echoing it.
    let e = client.cancel(424242).unwrap();
    assert_eq!(e.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(e.get("kind").and_then(Json::as_str), Some("bad_request"));
    assert_eq!(e.req_usize("req_id").unwrap(), 424242);

    // v2 streaming: frames carry req_id, the final is tagged and typed.
    let (frames, fin) = client
        .generate_stream_with(LONG_PROMPT, "translate", 12, &GenOptions::default())
        .unwrap();
    assert!(!frames.is_empty());
    for f in &frames {
        assert_eq!(f.req_usize("req_id").unwrap(), 12);
    }
    assert_eq!(fin.get("frame").and_then(Json::as_str), Some("final"));
    assert!(fin.get("finish").and_then(Json::as_str).is_some());

    // Lifecycle metrics made it to the wire.
    let mut m = Json::obj();
    m.set("cmd", Json::Str("metrics".into()));
    let metrics = client.call(&m).unwrap();
    assert!(metrics.get("finish_stop").is_some());
    assert!(metrics.get("deadline_miss_rate").is_some());
    assert!(metrics.get("slo_interactive").and_then(Json::as_usize).unwrap_or(0) >= 3);

    stop_server(coord, server, &mut client);
}

#[test]
fn wire_cancel_reaches_a_streaming_request() {
    if !have_artifacts() {
        return;
    }
    let (coord, server) = start_server(cfg());
    let mut a = Client::connect(server.port).unwrap();
    let mut b = Client::connect(server.port).unwrap();

    // A opens a v2 streaming request; after its first frame, B cancels
    // it by req_id from a different connection.
    let line = format!(
        r#"{{"v":2,"req_id":77,"stream":true,"prompt":"{LONG_PROMPT}","task":"translate"}}"#
    );
    a.send(&Json::parse(&line).unwrap()).unwrap();
    let first = a.read_reply().unwrap();
    assert_eq!(first.get("frame").and_then(Json::as_str), Some("tokens"), "{first}");
    let cancel_reply = b.cancel(77).unwrap();
    // Drain A's stream to its terminating line.
    let fin = loop {
        let line = a.read_reply().unwrap();
        if line.get("frame").and_then(Json::as_str) != Some("tokens") {
            break line;
        }
    };
    // The cancel either caught the live request (ok reply, and A's
    // final reports cancelled unless the decode finished in the race
    // window) or arrived after completion (typed bad_request). Either
    // way both sides see a coherent, typed story.
    if cancel_reply.get("ok") == Some(&Json::Bool(true)) {
        let finish = fin.get("finish").and_then(Json::as_str);
        assert!(
            finish == Some("cancelled")
                || fin.get("kind").and_then(Json::as_str) == Some("cancelled")
                || finish == Some("stop")
                || finish == Some("length"),
            "unexpected final after cancel: {fin}"
        );
    } else {
        assert_eq!(cancel_reply.get("kind").and_then(Json::as_str), Some("bad_request"));
    }

    stop_server(coord, server, &mut a);
}
