//! Overload-shedding and serving-lifecycle end-to-end tests over real
//! TCP (skipped when `make artifacts` hasn't run): admission-queue
//! overflow sheds with the typed `overloaded` taxonomy while survivors'
//! frame streams stay intact and KV pages reclaim immediately; the
//! per-client token bucket returns `retry_after_ms` the typed client
//! surfaces as [`ClientError::Overloaded`]; graceful drain completes
//! in-flight streams, rejects new work with a typed reply, and exits the
//! serving thread; and `{"cmd":"reload"}` hot-applies exactly the
//! admission-boundary-safe knobs while reporting engine knobs as ignored.

use specedge::api::GenOptions;
use specedge::config::{KvCacheMode, RunConfig, ServeMode};
use specedge::coordinator::Coordinator;
use specedge::server::{Backend, Client, ClientError, ServeOptions, Server};
use specedge::tokenizer::Tokenizer;
use specedge::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn have_artifacts() -> bool {
    if Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        false
    }
}

/// Same long eval prompt the lifecycle tests pin: γ=1 decodes span many
/// rounds, so overload events land mid-decode, not between requests.
const LONG_PROMPT: &str = "tr: mogdi mogdi peni ture buda ture hevboco curih ture milori";

fn base_cfg() -> RunConfig {
    RunConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        max_new_tokens: 64,
        gamma: Some(1),
        max_inflight: 1,
        workers: 1,
        ..RunConfig::default()
    }
}

fn start_server(c: RunConfig) -> (Arc<Coordinator>, Server) {
    let coord = Arc::new(Coordinator::start(c, specedge::hetero::Platform::imx95()).unwrap());
    let server = Server::start(Arc::clone(&coord), Tokenizer::builtin(), 0).unwrap();
    (coord, server)
}

fn start_server_opts(c: RunConfig, opts: ServeOptions) -> (Arc<Coordinator>, Server) {
    let coord = Arc::new(Coordinator::start(c, specedge::hetero::Platform::imx95()).unwrap());
    let server =
        Server::start_opts(Backend::Single(Arc::clone(&coord)), Tokenizer::builtin(), 0, opts)
            .unwrap();
    (coord, server)
}

fn stop(coord: Arc<Coordinator>, server: Server) {
    server.stop();
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
}

/// Admission-queue overflow: with a 2-deep queue and one slot, a burst
/// of concurrent streaming requests must split into survivors (complete,
/// frame-intact streams) and typed `overloaded` sheds carrying the queue
/// state — and once the burst resolves, every KV page is back in the
/// pool and the sheds are visible in the lifecycle metrics.
#[test]
fn queue_overflow_sheds_typed_while_survivors_stay_intact() {
    if !have_artifacts() {
        return;
    }
    const N: usize = 6;
    let cfg = RunConfig {
        queue_capacity: 2,
        kv_cache: KvCacheMode::On,
        ..base_cfg()
    };
    let (coord, server) = start_server(cfg);
    let port = server.port;

    // Connect everyone first, then fire all requests in one burst so the
    // queue genuinely overflows (connects are µs, decodes are ms+).
    let clients: Vec<Client> = (0..N)
        .map(|_| {
            let mut c = Client::connect(port).unwrap();
            c.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
            c
        })
        .collect();
    let workers: Vec<_> = clients
        .into_iter()
        .enumerate()
        .map(|(i, mut c)| {
            std::thread::spawn(move || {
                c.generate_stream_with(
                    LONG_PROMPT,
                    "translate",
                    100 + i as u64,
                    &GenOptions::default(),
                )
                .unwrap()
            })
        })
        .collect();

    let mut survivors = 0usize;
    let mut shed = 0usize;
    for w in workers {
        let (frames, fin) = w.join().unwrap();
        if fin.get("ok") == Some(&Json::Bool(true)) {
            survivors += 1;
            // Zero lost or corrupted frames: rounds strictly increase,
            // the stream terminates with done, and the frames reassemble
            // to exactly the final's token count.
            assert!(!frames.is_empty(), "survivor streamed nothing: {fin}");
            let mut last_round = 0usize;
            let mut streamed = 0usize;
            for f in &frames {
                let round = f.req_usize("round").unwrap();
                assert!(round > last_round, "non-monotone rounds: {f}");
                last_round = round;
                streamed += f.req_usize("n_tokens").unwrap();
            }
            assert_eq!(frames.last().unwrap().get("done"), Some(&Json::Bool(true)));
            assert_eq!(streamed, fin.req_usize("tokens").unwrap(), "{fin}");
            assert_eq!(fin.get("finish").and_then(Json::as_str), Some("stop"));
        } else {
            shed += 1;
            // The typed overload taxonomy, with queue state for backoff.
            assert!(frames.is_empty(), "shed request must not stream");
            assert_eq!(fin.get("kind").and_then(Json::as_str), Some("overloaded"), "{fin}");
            assert!(
                fin.req_str("error").unwrap().starts_with("queue full"),
                "{fin}"
            );
            assert_eq!(fin.req_usize("queue_capacity").unwrap(), 2);
            assert!(fin.get("queue_len").and_then(Json::as_usize).is_some());
        }
    }
    // One decoding + two queued survive at minimum; with a 2-deep queue
    // at least three of six must shed.
    assert_eq!(survivors + shed, N);
    assert!(survivors >= 2, "survivors {survivors}");
    assert!(shed >= 3, "shed {shed}");

    // Post-burst engine state: sheds counted, every KV page reclaimed.
    let mut probe = Client::connect(port).unwrap();
    let mut m = Json::obj();
    m.set("cmd", Json::Str("metrics".into()));
    let metrics = probe.call(&m).unwrap();
    assert_eq!(metrics.req_usize("finish_rejected").unwrap(), shed);
    assert_eq!(metrics.req_usize("kv_pages_used_cpu").unwrap(), 0);
    assert_eq!(metrics.req_usize("kv_pages_used_gpu").unwrap(), 0);
    assert!(metrics.req_usize("kv_lookups").unwrap() >= survivors);

    stop(coord, server);
}

/// The per-client token bucket sheds with `retry_after_ms`, surfaced by
/// the typed client as [`ClientError::Overloaded`] with a concrete
/// [`ClientError::retry_after`] hint — on both v2 and v1 lines.
#[test]
fn rate_limit_returns_typed_retry_after() {
    if !have_artifacts() {
        return;
    }
    let opts = ServeOptions {
        rate_limit_rps: 0.01,
        rate_limit_burst: 1,
        ..ServeOptions::default()
    };
    let (coord, server) = start_server_opts(base_cfg(), opts);
    let mut c = Client::connect_timeout(server.port, Duration::from_secs(5)).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();

    // The burst token admits the first request...
    let r = c
        .try_generate_with("tr: a", "translate", 1, &GenOptions::default())
        .unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));

    // ...and the second is shed with a usable backoff hint (~100 s at
    // 0.01 rps).
    let e = c
        .try_generate_with("tr: a", "translate", 2, &GenOptions::default())
        .unwrap_err();
    assert!(e.is_overloaded(), "{e}");
    let backoff = e.retry_after().expect("rate-limit shed must carry retry_after_ms");
    assert!(backoff > Duration::from_secs(1), "{backoff:?}");

    // v1 lines classify identically (message-prefix taxonomy).
    let e = c.try_generate("tr: a", "translate").unwrap_err();
    assert!(e.is_overloaded(), "{e}");
    assert!(e.retry_after().is_some());

    stop(coord, server);
}

/// Graceful drain: in-flight streams run to their normal completion
/// (zero dropped frames), post-drain generates get a typed rejection,
/// and the serving thread then exits on its own.
#[test]
fn drain_completes_inflight_rejects_new_and_exits() {
    if !have_artifacts() {
        return;
    }
    let (coord, mut server) = start_server(base_cfg());
    let mut a = Client::connect(server.port).unwrap();
    a.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut b = Client::connect(server.port).unwrap();
    b.set_read_timeout(Some(Duration::from_secs(30))).unwrap();

    // A's stream is provably mid-decode when the drain lands.
    let line = format!(
        r#"{{"v":2,"req_id":9,"stream":true,"prompt":"{LONG_PROMPT}","task":"translate"}}"#
    );
    a.send(&Json::parse(&line).unwrap()).unwrap();
    let first = a.read_reply().unwrap();
    assert_eq!(first.get("frame").and_then(Json::as_str), Some("tokens"), "{first}");

    // Drain over the wire (the programmatic twin is Server::drain).
    let mut d = Json::obj();
    d.set("cmd", Json::Str("drain".into()));
    let ack = b.call(&d).unwrap();
    assert_eq!(ack.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(ack.get("draining"), Some(&Json::Bool(true)));
    assert!(server.draining());

    // New work on an existing connection: typed overload rejection.
    let e = b
        .try_generate_with(LONG_PROMPT, "translate", 10, &GenOptions::default())
        .unwrap_err();
    assert!(e.is_overloaded(), "{e}");
    match &e {
        ClientError::Overloaded { msg, .. } => {
            assert!(msg.starts_with("draining"), "{msg}")
        }
        other => panic!("expected Overloaded, got {other}"),
    }

    // The in-flight stream still runs to its natural end: frames keep
    // coming, the final is ok:true with the normal finish.
    let mut frames = vec![first];
    let fin = loop {
        let l = a.read_reply().unwrap();
        if l.get("frame").and_then(Json::as_str) == Some("tokens") {
            frames.push(l);
        } else {
            break l;
        }
    };
    assert_eq!(fin.get("ok"), Some(&Json::Bool(true)), "{fin}");
    let finish = fin.get("finish").and_then(Json::as_str).unwrap();
    assert!(finish == "stop" || finish == "length", "{fin}");
    assert_eq!(frames.last().unwrap().get("done"), Some(&Json::Bool(true)));
    let streamed: usize = frames
        .iter()
        .map(|f| f.req_usize("n_tokens").unwrap())
        .sum();
    assert_eq!(streamed, fin.req_usize("tokens").unwrap());

    // Drain finished -> the serving thread exits without a shutdown cmd.
    server.wait();
    drop(server);
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
}

/// `{"cmd":"reload"}` hot-applies the admission-boundary-safe knobs to
/// live connections, reports engine knobs as ignored, and rejects
/// invalid configs atomically (validated on a probe before anything is
/// applied).
#[test]
fn reload_applies_shell_knobs_and_ignores_engine_knobs() {
    if !have_artifacts() {
        return;
    }
    let (coord, server) = start_server(base_cfg());
    let mut c = Client::connect(server.port).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(60))).unwrap();

    // Mixed reload: two shell knobs, one engine knob.
    let mut r = Json::obj();
    r.set("cmd", Json::Str("reload".into())).set(
        "config",
        Json::parse(r#"{"rate_limit_rps":0.01,"rate_limit_burst":1,"gamma":3}"#).unwrap(),
    );
    let reply = c.call(&r).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(true)), "{reply}");
    let applied: Vec<&str> = reply
        .req_arr("applied")
        .unwrap()
        .iter()
        .filter_map(Json::as_str)
        .collect();
    let ignored: Vec<&str> = reply
        .req_arr("ignored")
        .unwrap()
        .iter()
        .filter_map(Json::as_str)
        .collect();
    assert!(applied.contains(&"rate_limit_rps"), "{reply}");
    assert!(applied.contains(&"rate_limit_burst"), "{reply}");
    assert!(ignored.contains(&"gamma"), "{reply}");

    // The reloaded limit binds at this connection's next admission.
    let ok = c
        .try_generate_with("tr: a", "translate", 1, &GenOptions::default())
        .unwrap();
    assert_eq!(ok.get("ok"), Some(&Json::Bool(true)));
    let e = c
        .try_generate_with("tr: a", "translate", 2, &GenOptions::default())
        .unwrap_err();
    assert!(e.is_overloaded(), "{e}");

    // Invalid configs are rejected atomically with a typed bad_request.
    let mut bad = Json::obj();
    bad.set("cmd", Json::Str("reload".into()))
        .set("config", Json::parse(r#"{"gamma":0}"#).unwrap());
    let reply = c.call(&bad).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(reply.get("kind").and_then(Json::as_str), Some("bad_request"));
    assert!(reply.req_str("error").unwrap().starts_with("invalid config"), "{reply}");

    // Reload without a config object: pinned bad_request.
    let mut none = Json::obj();
    none.set("cmd", Json::Str("reload".into()));
    let reply = c.call(&none).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)));
    assert!(reply.req_str("error").unwrap().contains("requires a `config` object"));

    // The reload counter made it to the serve metrics.
    let mut m = Json::obj();
    m.set("cmd", Json::Str("metrics".into()));
    let metrics = c.call(&m).unwrap();
    assert_eq!(metrics.req_usize("serve_reloads").unwrap(), 1);

    stop(coord, server);
}

/// The threaded shell serves the same protocol: a quick roundtrip under
/// `serve_mode: threaded` (the legacy thread-per-connection baseline the
/// event loop is benchmarked against).
#[test]
fn threaded_shell_still_serves_and_drains() {
    if !have_artifacts() {
        return;
    }
    let opts = ServeOptions { mode: ServeMode::Threaded, ..ServeOptions::default() };
    let (coord, mut server) = start_server_opts(base_cfg(), opts);
    let mut c = Client::connect(server.port).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let r = c.generate(LONG_PROMPT, "translate").unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert!(r.req_usize("tokens").unwrap() > 0);

    // Programmatic drain stops the threaded shell too (its handlers exit
    // at the next poll boundary).
    server.drain();
    server.wait();
    drop(server);
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
}
