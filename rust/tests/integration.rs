//! Cross-module integration tests that do NOT need artifacts on disk
//! (manifest-level plumbing, DSE + latency model + cost model composition,
//! workload + tokenizer agreement). Engine-level tests live in
//! runtime_e2e.rs / coordinator_e2e.rs (those require `make artifacts`).

use specedge::config::{ExecMode, KernelPath, RunConfig};
use specedge::costmodel;
use specedge::dse::{self, PairConfig};
use specedge::hetero::{LatencyModel, Mapping, Platform};
use specedge::models::{Scheme, VariantKey};
use specedge::runtime::Manifest;
use specedge::tokenizer::Tokenizer;
use specedge::util::json::Json;
use specedge::workload::Workload;
use std::path::Path;

fn mini_manifest() -> Manifest {
    let j = Json::parse(
        r#"{
      "tokenizer": {"specials":["<pad>","<bos>","<eos>","="],
                    "chars":" abcdefghijklmnopqrstuvwxyz.,?!-0123456789:'",
                    "vocab_size":48},
      "seq_buckets": [16, 32, 48, 64, 96, 128],
      "batch_sizes": [1, 4],
      "models": {
        "target": {"name":"target","n_layers":4,"d_model":128,"n_heads":4,
                   "ffn_dim":352,"vocab":48,"param_count":816256},
        "drafter": {"name":"drafter","n_layers":2,"d_model":96,"n_heads":4,
                    "ffn_dim":256,"vocab":48,"param_count":230880}
      },
      "quant": {"qmax": 2},
      "variants": {},
      "monolithic": [],
      "eval_samples": [
        {"task":"translate","prompt":"tr: cela vodu","completion":"jlsh cvkb"},
        {"task":"copy","prompt":"cp: abc def","completion":"abc def"},
        {"task":"translate","prompt":"tr: nene","completion":"ulul"}
      ]}"#,
    )
    .unwrap();
    Manifest::from_json(Path::new("/tmp/x"), &j).unwrap()
}

#[test]
fn full_decision_pipeline_composes() {
    // manifest -> specs -> latency model -> DSE -> cost model, end to end.
    let m = mini_manifest();
    let lat = LatencyModel::new(Platform::imx95());
    let pair = PairConfig {
        target: m.model_for(VariantKey::parse("target_w8a8").unwrap()).unwrap().clone(),
        target_scheme: Scheme::W8a8,
        drafter: m.model_for(VariantKey::parse("drafter_fp").unwrap()).unwrap().clone(),
        drafter_scheme: Scheme::Fp,
    };
    let decisions = dse::explore_all(&lat, &pair, 0.90, 63);
    assert_eq!(decisions.len(), 6);
    // Variant 1's winning mapping must be drafter@GPU / target@1-core-CPU.
    let v1 = &decisions[0].best;
    assert_eq!(v1.mapping, Mapping::heterogeneous(1));
    // And its speedup must equal Eq. (1) at its own (c, γ).
    let expect = costmodel::speedup(0.90, v1.gamma, v1.c);
    assert!((v1.speedup - expect).abs() < 1e-12);
}

#[test]
fn workload_tokenizer_agreement() {
    let m = mini_manifest();
    let t = Tokenizer::from_manifest(&m.tokenizer_spec).unwrap();
    let w = Workload::from_manifest(&m, &t, Some("translate"), None).unwrap();
    assert_eq!(w.requests.len(), 2);
    for r in &w.requests {
        // prompt = BOS + text + SEP, decodable back to "<text>=".
        let text = t.decode(&r.prompt);
        assert!(text.starts_with("tr: "));
        assert!(text.ends_with('='));
    }
}

#[test]
fn config_json_to_platform_pipeline() {
    let mut cfg = RunConfig::default();
    cfg.apply_json(
        &Json::parse(
            r#"{"exec_mode":"monolithic","kernel_path":"ref",
                "design_variant":2,"gamma":3}"#,
        )
        .unwrap(),
    )
    .unwrap();
    assert_eq!(cfg.exec_mode, ExecMode::Monolithic);
    assert_eq!(cfg.kernel_path, KernelPath::Ref);
    let platform = Platform::imx95();
    let lat = LatencyModel::new(platform);
    // The config's variant is usable directly as a mapping core count.
    let m = Mapping::heterogeneous(cfg.design_variant);
    let spec = mini_manifest()
        .model_for(VariantKey::parse("drafter_fp").unwrap())
        .unwrap()
        .clone();
    assert!(lat.forward_latency(&spec, Scheme::Fp, m.drafter, 63) > 0.0);
}

#[test]
fn bucket_selection_matches_decode_needs() {
    let m = mini_manifest();
    // A 63-token prompt drafting 5 ahead needs the 96 bucket once past 64.
    assert_eq!(m.bucket_for(63), Some(64));
    assert_eq!(m.bucket_for(64 + 5), Some(96));
    assert_eq!(m.bucket_for(128), Some(128));
    assert_eq!(m.bucket_for(129), None);
}

#[test]
fn table2_table3_contrast() {
    // The same platform + pair flips from "speculate" to "don't" purely on
    // α — the paper's central Table II vs Table III contrast.
    let m = mini_manifest();
    let lat = LatencyModel::new(Platform::imx95());
    let pair = PairConfig {
        target: m.model_for(VariantKey::parse("target_w8a8").unwrap()).unwrap().clone(),
        target_scheme: Scheme::W8a8,
        drafter: m.model_for(VariantKey::parse("drafter_fp").unwrap()).unwrap().clone(),
        drafter_scheme: Scheme::Fp,
    };
    let high = dse::explore_all(&lat, &pair, 0.90, 63);
    let low = dse::explore_all(&lat, &pair, 0.17, 63);
    assert!(high.iter().any(|d| d.best.gamma > 0));
    assert!(low.iter().all(|d| d.best.gamma == 0));
}

#[test]
fn headline_speedup_from_calibrated_platform() {
    // The 1.68× headline must emerge from the *platform model*, not a
    // hard-coded constant: recompute c from the latency model and evaluate
    // Eq. (1) at the paper's α = 0.90.
    let m = mini_manifest();
    let lat = LatencyModel::new(Platform::imx95());
    let d = m.model_for(VariantKey::parse("drafter_fp").unwrap()).unwrap();
    let t = m.model_for(VariantKey::parse("target_w8a8").unwrap()).unwrap();
    let c = lat.cost_coefficient(
        (d, Scheme::Fp), (t, Scheme::W8a8), Mapping::heterogeneous(1), 63);
    let best = costmodel::optimal_gamma(0.90, c);
    assert!((best.speedup - 1.68).abs() < 0.05, "S = {}", best.speedup);
}
