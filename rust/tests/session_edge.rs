//! Decode edge cases the old run-to-completion loops only handled
//! implicitly, now pinned down against the resumable `DecodeSession`
//! state machine: EOS landing inside the accepted draft prefix, the
//! correction token filling `max_new` exactly, and `gen_cap` collapsing
//! to 0 for prompts near the largest compiled bucket.
//!
//! The commit-transition tests are engine-free (they drive the public
//! `commit_round` / `SessionLimits` surface); the `step`-driven tests run
//! over the real AOT artifacts and skip when `make artifacts` hasn't run.

use specedge::config::{ExecMode, KernelPath};
use specedge::hetero::{LatencyModel, Mapping, Platform};
use specedge::models::VariantKey;
use specedge::runtime::Engine;
use specedge::spec::{AcceptRule, DecodeSession, Decoder, DecoderSetup, SessionLimits};
use specedge::tokenizer::{Tokenizer, EOS_ID, SEP_ID};
use std::path::Path;

fn setup(gamma: usize, max_new: usize) -> DecoderSetup {
    DecoderSetup {
        drafter: VariantKey::parse("drafter_fp").unwrap(),
        target: VariantKey::parse("target_w8a8").unwrap(),
        kernel: KernelPath::Pallas,
        mapping: Mapping::heterogeneous(1),
        gamma,
        rule: AcceptRule::Greedy,
        exec: ExecMode::Modular,
        max_new,
    }
}

fn session_with_cap(cap: usize) -> DecodeSession {
    DecodeSession::with_limits(
        LatencyModel::new(Platform::imx95()),
        setup(5, cap),
        true,
        &[1, 9, 9],
        SessionLimits { cap, max_total: 128 },
    )
}

// ---- engine-free commit-transition edges --------------------------------

#[test]
fn eos_inside_accepted_prefix_ends_session_before_correction() {
    let mut s = session_with_cap(16);
    let done = s.commit_round(&[7, 8, EOS_ID, 10], 11);
    assert!(done && s.is_done());
    // Tokens before EOS commit; EOS itself, the rest of the prefix and the
    // correction must all be discarded.
    assert_eq!(s.into_outcome().tokens, vec![7, 8]);
}

#[test]
fn correction_token_lands_exactly_at_max_new() {
    // cap 4: three accepted drafts leave exactly one slot, which the
    // correction fills — the session must finish with precisely max_new
    // tokens, correction included.
    let mut s = session_with_cap(4);
    let done = s.commit_round(&[7, 8, 10], 11);
    assert!(done && s.is_done(), "correction landed exactly on the cap");
    let out = s.into_outcome();
    assert_eq!(out.tokens, vec![7, 8, 10, 11]);

    // One round earlier (cap 5) the same commit leaves a slot open.
    let mut s = session_with_cap(5);
    assert!(!s.commit_round(&[7, 8, 10], 11));
    assert!(!s.is_done());
}

#[test]
fn accepted_prefix_saturates_cap_and_drops_correction() {
    let mut s = session_with_cap(2);
    assert!(s.commit_round(&[7, 8, 10], 11));
    assert_eq!(s.into_outcome().tokens, vec![7, 8]);
}

#[test]
fn gen_cap_zero_for_prompt_near_largest_bucket() {
    // γ=5 window: anything closer than prompt + γ to the bucket edge
    // leaves no decodable room.
    assert_eq!(SessionLimits::compute(64, 123, 5, 128), 0);
    assert_eq!(SessionLimits::compute(64, 128, 5, 128), 0);
    assert_eq!(SessionLimits::compute(64, 122, 5, 128), 1);
    // Baseline counts a 1-token window even with γ=0 admission.
    assert_eq!(SessionLimits::compute(64, 127, 0, 128), 0);
    assert_eq!(SessionLimits::compute(64, 126, 0, 128), 1);
    // A 0-cap session is born finished and yields an empty outcome.
    let s = session_with_cap(0);
    assert!(s.is_done());
    assert!(s.into_outcome().tokens.is_empty());
}

// ---- step-driven edges over the real artifacts --------------------------

fn engine() -> Option<Engine> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(dir).expect("engine"))
}

fn test_prompt(engine: &Engine) -> Vec<u32> {
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec).unwrap();
    let s = engine
        .manifest
        .eval_samples
        .iter()
        .find(|s| s.task == "translate")
        .expect("translate sample");
    let mut ids = tokenizer.encode(&s.prompt, true).unwrap();
    ids.push(SEP_ID);
    ids
}

#[test]
fn stepping_to_completion_matches_one_shot_decode() {
    let Some(engine) = engine() else { return };
    let prompt = test_prompt(&engine);
    let lat = LatencyModel::new(Platform::imx95());
    let decoder = Decoder::new(&engine, lat.clone(), setup(3, 24));

    let mut session =
        DecodeSession::new(&engine, lat, setup(3, 24), true, &prompt);
    let mut steps = 0usize;
    let mut streamed: Vec<u32> = Vec::new();
    let mut sim_sum = 0.0;
    while !session.is_done() {
        let s = session.step(&engine).unwrap();
        streamed.extend(&s.committed);
        sim_sum += s.sim_s;
        steps += 1;
    }
    let stepped = session.into_outcome();
    let oneshot = decoder.speculative(&prompt).unwrap();

    assert_eq!(stepped.tokens, oneshot.tokens);
    assert_eq!(stepped.n_rounds, oneshot.n_rounds);
    assert_eq!(stepped.n_drafted, oneshot.n_drafted);
    assert_eq!(stepped.n_accepted, oneshot.n_accepted);
    assert!((stepped.sim_s - oneshot.sim_s).abs() < 1e-12);
    // Per-step deltas must tile the aggregate exactly.
    assert_eq!(streamed, stepped.tokens);
    assert!((sim_sum - stepped.sim_s).abs() < 1e-9);
    assert_eq!(steps, stepped.n_rounds);
}

#[test]
fn session_respects_exact_max_new_boundary() {
    let Some(engine) = engine() else { return };
    let prompt = test_prompt(&engine);
    let lat = LatencyModel::new(Platform::imx95());
    for max_new in [1usize, 2, 3, 5] {
        let mut session =
            DecodeSession::new(&engine, lat.clone(), setup(4, max_new), true, &prompt);
        while !session.is_done() {
            session.step(&engine).unwrap();
        }
        let out = session.into_outcome();
        assert!(
            out.tokens.len() <= max_new,
            "max_new={max_new} produced {} tokens",
            out.tokens.len()
        );
    }
}

#[test]
fn gamma_change_between_rounds_keeps_greedy_exactness() {
    let Some(engine) = engine() else { return };
    let prompt = test_prompt(&engine);
    let lat = LatencyModel::new(Platform::imx95());
    let baseline = Decoder::new(&engine, lat.clone(), setup(1, 20))
        .baseline(&prompt)
        .unwrap();

    let mut session =
        DecodeSession::new(&engine, lat, setup(1, 20), true, &prompt);
    let gammas = [1usize, 5, 2, 4, 3];
    let mut round = 0usize;
    while !session.is_done() {
        session.set_gamma(gammas[round % gammas.len()]);
        session.step(&engine).unwrap();
        round += 1;
    }
    let out = session.into_outcome();
    // Greedy speculative decoding is exact whatever γ schedule ran.
    let n = out.tokens.len().min(baseline.tokens.len());
    assert!(n > 0);
    assert_eq!(out.tokens[..n], baseline.tokens[..n]);
}

#[test]
fn stepping_a_finished_session_is_a_noop() {
    let Some(engine) = engine() else { return };
    let prompt = test_prompt(&engine);
    let lat = LatencyModel::new(Platform::imx95());
    let mut session =
        DecodeSession::new(&engine, lat, setup(3, 4), true, &prompt);
    while !session.is_done() {
        session.step(&engine).unwrap();
    }
    let before = session.outcome().clone();
    let s = session.step(&engine).unwrap();
    assert!(s.done && s.committed.is_empty() && s.sim_s == 0.0);
    let after = session.outcome();
    assert_eq!(before.tokens, after.tokens);
    assert_eq!(before.target_calls, after.target_calls);
}
