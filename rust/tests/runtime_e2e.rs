//! Engine-level end-to-end tests over the real AOT artifacts.
//!
//! These need `make artifacts` to have run (skipped with a clear message
//! otherwise, so `cargo test` stays green on a fresh checkout).

use specedge::config::{ExecMode, KernelPath};
use specedge::hetero::{LatencyModel, Mapping, Platform};
use specedge::models::VariantKey;
use specedge::runtime::Engine;
use specedge::spec::{AcceptRule, Decoder, DecoderSetup};
use specedge::tokenizer::{Tokenizer, SEP_ID};
use std::path::Path;

fn engine() -> Option<Engine> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    Some(Engine::load(dir).expect("engine"))
}

fn test_prompt(engine: &Engine, tokenizer: &Tokenizer) -> Vec<u32> {
    let s = engine
        .manifest
        .eval_samples
        .iter()
        .find(|s| s.task == "translate")
        .expect("translate sample");
    let mut ids = tokenizer.encode(&s.prompt, true).unwrap();
    ids.push(SEP_ID);
    ids
}

#[test]
fn forward_shapes_and_determinism() {
    let Some(engine) = engine() else { return };
    let v = VariantKey::parse("drafter_fp").unwrap();
    let tokens: Vec<u32> = (4..20).collect();
    let a = engine.forward(v, KernelPath::Pallas, &tokens, 32).unwrap();
    assert_eq!((a.batch, a.seq, a.vocab), (1, 32, 48));
    assert!(a.logits.iter().all(|x| x.is_finite()));
    let b = engine.forward(v, KernelPath::Pallas, &tokens, 32).unwrap();
    assert_eq!(a.logits, b.logits, "same input must give identical logits");
}

#[test]
fn pallas_and_ref_artifacts_agree() {
    // The L1 deliverable check at the artifact level: the Pallas-kernel
    // lowering and the pure-jnp lowering must produce (near-)identical
    // logits through the whole PJRT path.
    let Some(engine) = engine() else { return };
    for key in ["drafter_fp", "target_fp", "target_w8a8", "drafter_w8a8"] {
        let v = VariantKey::parse(key).unwrap();
        let tokens: Vec<u32> = (4..40).map(|i| 4 + (i % 40)).collect();
        let p = engine.forward(v, KernelPath::Pallas, &tokens, 48).unwrap();
        let r = engine.forward(v, KernelPath::Ref, &tokens, 48).unwrap();
        let live = tokens.len() * p.vocab;
        for i in 0..live {
            assert!(
                (p.logits[i] - r.logits[i]).abs() < 1e-3,
                "{key}: pallas vs ref logit {i}: {} vs {}",
                p.logits[i], r.logits[i]
            );
        }
    }
}

#[test]
fn bucket_padding_invariance_through_pjrt() {
    // The causal-masking property the bucketed runtime relies on, verified
    // end-to-end through XLA: live-position logits identical across buckets.
    let Some(engine) = engine() else { return };
    let v = VariantKey::parse("target_w8a8").unwrap();
    let tokens: Vec<u32> = (0..14).map(|i| 5 + i % 30).collect();
    let small = engine.forward(v, KernelPath::Pallas, &tokens, 16).unwrap();
    let big = engine.forward(v, KernelPath::Pallas, &tokens, 64).unwrap();
    for pos in 0..tokens.len() {
        let a = small.row(0, pos);
        let b = big.row(0, pos);
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() < 1e-3, "pos {pos} logit {i}");
        }
    }
}

#[test]
fn batched_forward_matches_single() {
    let Some(engine) = engine() else { return };
    let v = VariantKey::parse("target_fp").unwrap();
    let s1: Vec<u32> = (4..20).collect();
    let s2: Vec<u32> = (10..24).collect();
    let s3: Vec<u32> = vec![1, 5, 6, 7];
    let s4: Vec<u32> = (4..16).rev().collect();
    let batch = engine
        .forward_batch(v, KernelPath::Ref,
                       &[&s1, &s2, &s3, &s4], 32)
        .unwrap();
    for (bi, s) in [&s1, &s2, &s3, &s4].iter().enumerate() {
        let single = engine.forward(v, KernelPath::Ref, s, 32).unwrap();
        for pos in 0..s.len() {
            let a = batch.row(bi, pos);
            let b = single.row(0, pos);
            for i in 0..a.len() {
                assert!((a[i] - b[i]).abs() < 1e-3, "item {bi} pos {pos}");
            }
        }
    }
}

#[test]
fn modular_and_monolithic_agree() {
    // Greedy determinism ⇒ both executors must emit identical tokens and
    // identical accept counts (the monolithic graph is the fused version of
    // exactly the modular control flow).
    let Some(engine) = engine() else { return };
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec).unwrap();
    let prompt = test_prompt(&engine, &tokenizer);
    let lat = LatencyModel::new(Platform::imx95());
    let mk = |exec| DecoderSetup {
        drafter: VariantKey::parse("drafter_fp").unwrap(),
        target: VariantKey::parse("target_w8a8").unwrap(),
        kernel: KernelPath::Pallas,
        mapping: Mapping::heterogeneous(1),
        gamma: 3,
        rule: AcceptRule::Greedy,
        exec,
        max_new: 24,
    };
    let modular = Decoder::new(&engine, lat.clone(), mk(ExecMode::Modular))
        .speculative(&prompt)
        .unwrap();
    let mono = Decoder::new(&engine, lat, mk(ExecMode::Monolithic))
        .speculative(&prompt)
        .unwrap();
    assert_eq!(modular.tokens, mono.tokens);
    assert_eq!(modular.n_accepted, mono.n_accepted);
    assert_eq!(modular.n_drafted, mono.n_drafted);
}

#[test]
fn speculative_matches_baseline_tokens() {
    // Greedy speculative decoding is *exact*: it must reproduce the
    // baseline's greedy continuation token-for-token.
    let Some(engine) = engine() else { return };
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec).unwrap();
    let prompt = test_prompt(&engine, &tokenizer);
    let lat = LatencyModel::new(Platform::imx95());
    let setup = DecoderSetup {
        drafter: VariantKey::parse("drafter_fp").unwrap(),
        target: VariantKey::parse("target_w8a8").unwrap(),
        kernel: KernelPath::Pallas,
        mapping: Mapping::heterogeneous(1),
        gamma: 4,
        rule: AcceptRule::Greedy,
        exec: ExecMode::Modular,
        max_new: 20,
    };
    let decoder = Decoder::new(&engine, lat, setup);
    let base = decoder.baseline(&prompt).unwrap();
    let spec = decoder.speculative(&prompt).unwrap();
    let n = base.tokens.len().min(spec.tokens.len());
    assert!(n > 0);
    assert_eq!(base.tokens[..n], spec.tokens[..n],
               "speculative output diverged from greedy baseline");
    // Speculation must do strictly fewer target calls per token.
    assert!(spec.target_calls < base.target_calls);
    // And fewer simulated seconds on the calibrated variant-1 platform.
    assert!(spec.sim_s < base.sim_s, "{} !< {}", spec.sim_s, base.sim_s);
}

#[test]
fn alpha_accounting_consistent() {
    let Some(engine) = engine() else { return };
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec).unwrap();
    let prompt = test_prompt(&engine, &tokenizer);
    let lat = LatencyModel::new(Platform::imx95());
    let setup = DecoderSetup {
        gamma: 5,
        ..DecoderSetup {
            drafter: VariantKey::parse("drafter_fp").unwrap(),
            target: VariantKey::parse("target_w8a8").unwrap(),
            kernel: KernelPath::Pallas,
            mapping: Mapping::heterogeneous(1),
            gamma: 5,
            rule: AcceptRule::Greedy,
            exec: ExecMode::Modular,
            max_new: 32,
        }
    };
    let out = Decoder::new(&engine, lat, setup).speculative(&prompt).unwrap();
    assert!(out.n_accepted <= out.n_drafted);
    assert_eq!(out.drafter_calls, out.n_drafted);
    assert_eq!(out.target_calls, out.n_rounds);
    let a = out.alpha();
    assert!((0.0..=1.0).contains(&a), "{a}");
}

#[test]
fn mono_step_bounds() {
    let Some(engine) = engine() else { return };
    let tokens: Vec<u32> = (4..30).collect();
    for gamma in [1, 3, 5] {
        let step = engine.mono_step(gamma, &tokens, tokens.len()).unwrap();
        assert!(step.n_accepted <= gamma);
        assert_eq!(step.out_tokens.len(), gamma + 1);
        assert_eq!(step.drafted.len(), gamma);
        assert!(step.out_tokens.iter().all(|&t| (t as usize) < 48));
    }
}

#[test]
fn stochastic_rule_runs_and_accounts() {
    let Some(engine) = engine() else { return };
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec).unwrap();
    let prompt = test_prompt(&engine, &tokenizer);
    let lat = LatencyModel::new(Platform::imx95());
    let setup = DecoderSetup {
        drafter: VariantKey::parse("drafter_fp").unwrap(),
        target: VariantKey::parse("target_w8a8").unwrap(),
        kernel: KernelPath::Pallas,
        mapping: Mapping::heterogeneous(1),
        gamma: 3,
        rule: AcceptRule::Stochastic,
        exec: ExecMode::Modular,
        max_new: 16,
    };
    let decoder = Decoder::new(&engine, lat, setup);
    decoder.reseed(7);
    let out = decoder.speculative(&prompt).unwrap();
    assert!(!out.tokens.is_empty());
    assert!(out.n_accepted <= out.n_drafted);
}

#[test]
fn oversized_prompt_rejected() {
    let Some(engine) = engine() else { return };
    let tokens: Vec<u32> = vec![5; 200]; // > largest bucket (128)
    let err = engine.bucket_for(tokens.len()).unwrap_err().to_string();
    // The error is actionable: it names the requested length and lists
    // the manifest's compiled buckets.
    assert!(err.contains("200"), "{err}");
    for b in &engine.manifest.seq_buckets {
        assert!(err.contains(&b.to_string()), "bucket {b} missing: {err}");
    }
    let v = VariantKey::parse("drafter_fp").unwrap();
    assert!(engine.forward(v, KernelPath::Pallas, &tokens, 128).is_err());
}
