//! Fleet-routing end-to-end tests over the real artifacts (skipped when
//! `make artifacts` hasn't run): a fleet of one device must be
//! bit-identical to a plain coordinator, and a multi-device fleet must
//! spread load while serving every request.

use specedge::config::RunConfig;
use specedge::coordinator::Coordinator;
use specedge::fleet::{FleetRouter, FleetSpec};
use specedge::hetero::Platform;
use specedge::tokenizer::Tokenizer;
use specedge::workload::Request;
use std::path::{Path, PathBuf};

fn have_artifacts() -> bool {
    if Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        false
    }
}

fn cfg() -> RunConfig {
    RunConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        max_new_tokens: 16,
        gamma: Some(3),
        workers: 1,
        ..RunConfig::default()
    }
}

fn sample_request(id: u64, text: &str) -> Request {
    let t = Tokenizer::builtin();
    let mut prompt = t.encode(text, true).unwrap();
    prompt.push(specedge::tokenizer::SEP_ID);
    Request {
        id,
        task: "translate".into(),
        prompt,
        truth: String::new(),
        arrival_s: 0.0,
        class: None,
    }
}

const PROMPTS: [&str; 3] = ["tr: nene caka", "tr: bobo lulu", "tr: kaka nene didi"];

/// A fleet of exactly one device is the plain coordinator with a routing
/// tier in front — token streams must be bit-identical.
#[test]
fn fleet_of_one_matches_plain_coordinator() {
    if !have_artifacts() {
        return;
    }
    let fleet = FleetRouter::start(&cfg(), FleetSpec::homogeneous(1, Platform::imx95())).unwrap();
    let fleet_handles: Vec<_> = PROMPTS
        .iter()
        .enumerate()
        .map(|(i, p)| fleet.submit(sample_request(1 + i as u64, p)).handle)
        .collect();
    let fleet_streams: Vec<Vec<u32>> = fleet_handles
        .into_iter()
        .map(|h| h.wait().unwrap().tokens)
        .collect();
    let report = fleet.metrics().snapshot();
    assert_eq!(report.placements, vec![PROMPTS.len() as u64]);
    fleet.shutdown();

    let plain = Coordinator::start(cfg(), Platform::imx95()).unwrap();
    let plain_handles: Vec<_> = PROMPTS
        .iter()
        .enumerate()
        .map(|(i, p)| plain.submit(sample_request(1 + i as u64, p)))
        .collect();
    let plain_streams: Vec<Vec<u32>> = plain_handles
        .into_iter()
        .map(|h| h.wait().unwrap().tokens)
        .collect();
    plain.shutdown();

    assert!(fleet_streams.iter().all(|s| !s.is_empty()));
    assert_eq!(fleet_streams, plain_streams);
}

/// Two devices: every request is served, placements cover both devices,
/// and the streams are independent of which device served them (greedy
/// decode is device-agnostic).
#[test]
fn two_device_fleet_spreads_load_and_preserves_streams() {
    if !have_artifacts() {
        return;
    }
    let single = FleetRouter::start(&cfg(), FleetSpec::homogeneous(1, Platform::imx95())).unwrap();
    let expect: Vec<Vec<u32>> = PROMPTS
        .iter()
        .enumerate()
        .map(|(i, p)| single.submit(sample_request(1 + i as u64, p)).handle)
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.wait().unwrap().tokens)
        .collect();
    single.shutdown();

    let fleet = FleetRouter::start(&cfg(), FleetSpec::homogeneous(2, Platform::imx95())).unwrap();
    assert_eq!(fleet.device_count(), 2);
    let got: Vec<Vec<u32>> = PROMPTS
        .iter()
        .enumerate()
        .map(|(i, p)| fleet.submit(sample_request(1 + i as u64, p)).handle)
        .collect::<Vec<_>>()
        .into_iter()
        .map(|h| h.wait().unwrap().tokens)
        .collect();
    let report = fleet.metrics().snapshot();
    assert_eq!(report.placements.iter().sum::<u64>(), PROMPTS.len() as u64);
    assert!(
        report.placements.iter().all(|&p| p > 0),
        "placement starved a device: {:?}",
        report.placements
    );
    fleet.shutdown();
    assert_eq!(got, expect);
}
