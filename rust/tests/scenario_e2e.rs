//! Scenario-subsystem end-to-end tests at the decision level: generated
//! traces are decoded against a live [`Policy`] (admission consult, per
//! round re-consults, tagged retire feedback), with acceptances drawn
//! from each entry's true α regime. Covers the two scenario milestones
//! the unit tests can't: a two-class trace driving one policy to
//! *divergent* per-class drafter/γ decisions, and the single-class trace
//! under `drafter: fixed` staying bit-identical through the
//! drafter-aware route surface and the pre-registry one.

use specedge::api::SloClass;
use specedge::config::{DecisionMode, DrafterMode, RunConfig, TreeChoice};
use specedge::decision::{Policy, SpecHints};
use specedge::hetero::{Mapping, Platform};
use specedge::models::{ModelSpec, Scheme, VariantKey};
use specedge::runtime::Manifest;
use specedge::scenario::{
    ArrivalProcess, ClassMix, DrafterRegistry, RequestClass, ScenarioSpec, TraceEntry,
    WorkloadTrace,
};
use specedge::util::json::Json;
use specedge::util::rng::Rng;

/// Inline manifest with both drafter bodies — the registry source.
fn registry_manifest() -> Manifest {
    let j = Json::parse(
        r#"{
      "tokenizer": {"specials":["<pad>","<bos>","<eos>","="],
                    "chars":" abcdefghijklmnopqrstuvwxyz.,?!-0123456789:'",
                    "vocab_size":48},
      "seq_buckets": [128], "batch_sizes": [1],
      "models": {
        "target": {"name":"target","n_layers":4,"d_model":128,"n_heads":4,
                   "ffn_dim":352,"vocab":48,"param_count":816256},
        "drafter": {"name":"drafter","n_layers":2,"d_model":96,"n_heads":4,
                    "ffn_dim":256,"vocab":48,"param_count":230880}
      },
      "variants": {
        "drafter_fp": {"role":"drafter","scheme":"fp","model":"drafter",
          "weights":"w_dfp.bin","tensors":[],"artifacts":[]},
        "drafter_w8a8": {"role":"drafter","scheme":"w8a8","model":"drafter",
          "weights":"w_dq.bin","tensors":[],"artifacts":[]},
        "target_w8a8": {"role":"target","scheme":"w8a8","model":"target",
          "weights":"w_tq.bin","tensors":[],"artifacts":[]}
      },
      "monolithic": [], "eval_samples": []}"#,
    )
    .unwrap();
    Manifest::from_json(std::path::Path::new("/tmp"), &j).unwrap()
}

fn specs() -> (ModelSpec, ModelSpec) {
    (
        ModelSpec {
            name: "drafter".into(),
            n_layers: 2,
            d_model: 96,
            n_heads: 4,
            ffn_dim: 256,
            vocab: 48,
            param_count: 230_880,
        },
        ModelSpec {
            name: "target".into(),
            n_layers: 4,
            d_model: 128,
            n_heads: 4,
            ffn_dim: 352,
            vocab: 48,
            param_count: 816_256,
        },
    )
}

/// The 3-core homogeneous operating point (same as `experiment
/// scenarios`): heterogeneous mappings price out and the w8a8 target
/// keeps GPU mappings quantization-filtered, so drafter choice is the
/// live decision.
fn operating_cfg(drafter: DrafterMode) -> RunConfig {
    RunConfig {
        design_variant: 3,
        heterogeneous: false,
        decision: DecisionMode::Analytic,
        tree: TreeChoice::Off,
        speculative: true,
        gamma: None,
        repartition_every: 8,
        drafter,
        ..RunConfig::default()
    }
}

/// True per-drafter acceptance rate of one entry: fp drafts at the α
/// regime; quantized drafts keep it on the conversational classes but
/// collapse on the extractive ones (mirrors `experiment scenarios`).
fn true_alpha(e: &TraceEntry, scheme: Scheme) -> f64 {
    let quant = match e.class {
        RequestClass::Chat | RequestClass::Translate => 1.0,
        RequestClass::Summarize => 0.40,
        RequestClass::CodeComplete => 0.50,
    };
    match scheme {
        Scheme::Fp => e.alpha_regime,
        Scheme::W8a8 => (e.alpha_regime * quant).min(0.98),
    }
}

/// Decode every trace entry against `policy`, drawing acceptances from
/// the entry's true α under the session's drafter (seeded per entry, so
/// the same trace always replays identically). `legacy` drives the
/// pre-registry route/observe surface. Returns the full decision trail
/// plus the produced-token total — the bit-parity fingerprint.
fn decode(
    policy: &Policy,
    d: &ModelSpec,
    t: &ModelSpec,
    trace: &WorkloadTrace,
    legacy: bool,
) -> (Vec<(usize, bool, Mapping)>, u64) {
    let hints = SpecHints::default();
    let mut trail = Vec::new();
    let mut tokens = 0u64;
    for e in &trace.entries {
        let dk = if legacy { policy.variants().0 } else { policy.drafter_for(&e.task) };
        let adm = if legacy {
            policy.route_with(&e.task, d, t, 63, hints)
        } else {
            policy.route_with_drafter(&e.task, dk, d, t, 63, hints)
        };
        let mapping = adm.mapping;
        let alpha = true_alpha(e, dk.scheme);
        let mut rng = Rng::new(trace.seed ^ e.id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let (mut produced, mut drafted, mut accepted) = (0usize, 0usize, 0usize);
        while produced < e.max_new {
            let sa = if drafted == 0 {
                f64::NAN
            } else {
                accepted as f64 / drafted as f64
            };
            let dec = if legacy {
                policy.route_round_with(&e.task, d, t, mapping, 63, drafted, sa, hints)
            } else {
                policy.route_round_with_drafter(
                    &e.task, dk, d, t, mapping, 63, drafted, sa, hints,
                )
            };
            trail.push((dec.gamma, dec.speculative, dec.mapping));
            if dec.speculative && dec.gamma > 0 {
                let mut acc = 0;
                for _ in 0..dec.gamma {
                    if rng.f64() < alpha {
                        acc += 1;
                    } else {
                        break;
                    }
                }
                drafted += dec.gamma;
                accepted += acc;
                produced += acc + 1;
                let obs = acc as f64 / dec.gamma as f64;
                if legacy {
                    policy.observe_alpha(&e.task, obs);
                } else {
                    policy.observe_alpha_tagged(&e.task, dk, obs);
                }
            } else {
                produced += 1;
            }
        }
        tokens += produced as u64;
    }
    (trail, tokens)
}

fn two_class_spec() -> ScenarioSpec {
    let mix = |class, alpha| ClassMix {
        class,
        weight: 0.5,
        alpha,
        max_new: (12, 24),
        slo: SloClass::Interactive,
        deadline_s: None,
    };
    ScenarioSpec {
        name: "e2e_two_class".into(),
        seed: 0xE2E,
        requests: 160,
        arrivals: ArrivalProcess::Poisson { rate: 8.0 },
        mix: vec![
            mix(RequestClass::Translate, 0.90),
            mix(RequestClass::Summarize, 0.45),
        ],
    }
}

#[test]
fn two_class_trace_settles_classes_on_divergent_drafters() {
    let (d, t) = specs();
    let policy = Policy::new(&operating_cfg(DrafterMode::Auto), Platform::imx95()).unwrap();
    policy.set_drafter_registry(DrafterRegistry::from_manifest(&registry_manifest()).unwrap());
    let trace = two_class_spec().generate();
    assert_eq!(trace.class_count(), 2);
    decode(&policy, &d, &t, &trace, false);

    // Translate keeps its acceptances through quantization, so the
    // cheaper w8a8 body wins; summarize's collapse drives it back to fp.
    let fp = VariantKey::parse("drafter_fp").unwrap();
    let q = VariantKey::parse("drafter_w8a8").unwrap();
    assert_eq!(policy.chosen_drafter(RequestClass::Translate), Some(q));
    assert_eq!(policy.chosen_drafter(RequestClass::Summarize), Some(fp));
    assert_eq!(policy.drafter_for("translate"), q);
    assert_eq!(policy.drafter_for("initials"), fp);

    // The classes genuinely decide differently within the one run:
    // different drafter AND different γ at the settled state.
    let hints = SpecHints::default();
    let dec_tr = policy.route_with_drafter("translate", q, &d, &t, 63, hints);
    let dec_su = policy.route_with_drafter("initials", fp, &d, &t, 63, hints);
    assert!(dec_tr.speculative, "{dec_tr:?}");
    assert_ne!(dec_tr.gamma, dec_su.gamma, "{dec_tr:?} vs {dec_su:?}");
}

#[test]
fn single_class_fixed_trace_is_bit_identical_to_pre_registry_paths() {
    // The parity milestone: under `drafter: fixed` the drafter-aware
    // surface (what the worker now calls) must reproduce the historical
    // route/observe path decision-for-decision on a single-class trace.
    let (d, t) = specs();
    let spec = ScenarioSpec {
        name: "e2e_parity".into(),
        seed: 7,
        requests: 80,
        arrivals: ArrivalProcess::Poisson { rate: 8.0 },
        mix: vec![ClassMix {
            class: RequestClass::Translate,
            weight: 1.0,
            alpha: 0.90,
            max_new: (12, 24),
            slo: SloClass::Interactive,
            deadline_s: None,
        }],
    };
    let trace = spec.generate();
    let legacy = Policy::new(&operating_cfg(DrafterMode::Fixed), Platform::imx95()).unwrap();
    let tagged = Policy::new(&operating_cfg(DrafterMode::Fixed), Platform::imx95()).unwrap();
    let (trail_a, tokens_a) = decode(&legacy, &d, &t, &trace, true);
    let (trail_b, tokens_b) = decode(&tagged, &d, &t, &trace, false);
    assert_eq!(trail_a, trail_b);
    assert_eq!(tokens_a, tokens_b);
    for task in ["translate", "translate-rev"] {
        assert_eq!(
            legacy.alpha_estimate(task).to_bits(),
            tagged.alpha_estimate(task).to_bits(),
            "task {task} α estimate drifted"
        );
    }
    // Fixed mode accumulated no per-class selection state on either leg.
    for c in RequestClass::all() {
        assert_eq!(tagged.chosen_drafter(c), None);
    }
}

#[test]
fn saved_trace_replays_the_same_decision_trail() {
    // Replay determinism end to end: decoding the serialized-and-reloaded
    // trace on a fresh policy reproduces the decision trail and token
    // count of the original bit-for-bit.
    let (d, t) = specs();
    let trace = two_class_spec().generate();
    let reloaded = WorkloadTrace::from_jsonl(&trace.to_jsonl()).unwrap();
    let run = |tr: &WorkloadTrace| {
        let p = Policy::new(&operating_cfg(DrafterMode::Auto), Platform::imx95()).unwrap();
        p.set_drafter_registry(DrafterRegistry::from_manifest(&registry_manifest()).unwrap());
        decode(&p, &d, &t, tr, false)
    };
    let (trail_a, tokens_a) = run(&trace);
    let (trail_b, tokens_b) = run(&reloaded);
    assert_eq!(trail_a, trail_b);
    assert_eq!(tokens_a, tokens_b);
}
