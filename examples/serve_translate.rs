//! E2E serving driver (the validation workload recorded in EXPERIMENTS.md).
//!
//! Starts the full serving stack (coordinator + engine workers + TCP
//! front-end), replays the Spec-Bench-shaped translation workload with
//! Poisson arrivals through a real TCP client speaking the v2 wire
//! protocol (typed options, client-chosen req_ids, typed finish
//! reasons), and reports latency/throughput for three configurations:
//!
//!   1. baseline         — autoregressive decode, variant-1 CPU
//!   2. spec-homo        — speculative sampling, homogeneous 1-core mapping
//!   3. spec-hetero      — speculative sampling, drafter on the GPU
//!                         (the paper's deployed configuration)
//!
//! ```bash
//! cargo run --release --example serve_translate -- [n_requests] [rate_hz] [max_inflight]
//! ```
//!
//! Each worker interleaves up to `max_inflight` (default 4) sessions
//! round-by-round; the first request of each configuration streams its
//! incremental token frames, and the run ends with a
//! streaming-with-cancel demonstration: a second connection cancels a
//! live streamed request by req_id, which aborts at the next round
//! boundary with a typed `finish:"cancelled"`.

use specedge::api::GenOptions;
use specedge::config::RunConfig;
use specedge::coordinator::Coordinator;
use specedge::hetero::Platform;
use specedge::runtime::Manifest;
use specedge::server::{Client, Server};
use specedge::tokenizer::Tokenizer;
use specedge::util::json::Json;
use specedge::util::stats::Summary;
use specedge::workload::Workload;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

struct RunResult {
    name: &'static str,
    wall_s: f64,
    tokens: u64,
    sim_p50_ms: f64,
    sim_p90_ms: f64,
    real_p50_ms: f64,
    mean_alpha: f64,
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(12);
    let rate: f64 = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(2.0);
    let max_inflight: usize = args.get(2).and_then(|a| a.parse().ok()).unwrap_or(4);

    let manifest = Manifest::load(Path::new("artifacts"))?;
    let tokenizer = Tokenizer::from_manifest(&manifest.tokenizer_spec)?;
    let workload = Workload::from_manifest(&manifest, &tokenizer, Some("translate"),
                                           Some(n_requests))?
        .with_poisson_arrivals(rate, 42);
    println!(
        "workload: {} translate requests, Poisson {rate}/s, avg prompt {:.1} tokens, \
         {max_inflight} sessions in flight per worker",
        workload.requests.len(),
        workload.avg_prompt_len()
    );

    let configs: Vec<(&'static str, RunConfig)> = vec![
        ("baseline", {
            let mut c = base_cfg(max_inflight);
            c.speculative = false;
            c
        }),
        ("spec-homo", {
            let mut c = base_cfg(max_inflight);
            c.heterogeneous = false;
            c.gamma = Some(1); // homo mapping: cost model says γ small
            c
        }),
        ("spec-hetero", {
            let mut c = base_cfg(max_inflight);
            c.gamma = Some(5); // the paper's deployed config
            c
        }),
    ];

    let mut results = Vec::new();
    for (name, cfg) in configs {
        println!("\n=== {name} ===");
        results.push(run_one(name, cfg, &workload)?);
    }

    println!("\n{:<12} {:>8} {:>9} {:>12} {:>12} {:>12} {:>7}",
             "config", "wall s", "tokens/s", "sim p50 ms", "sim p90 ms",
             "real p50 ms", "alpha");
    let mut baseline_p50 = f64::NAN;
    for r in &results {
        if r.name == "baseline" {
            baseline_p50 = r.sim_p50_ms;
        }
        println!(
            "{:<12} {:>8.1} {:>9.1} {:>12.1} {:>12.1} {:>12.1} {:>7.2}",
            r.name,
            r.wall_s,
            r.tokens as f64 / r.wall_s,
            r.sim_p50_ms,
            r.sim_p90_ms,
            r.real_p50_ms,
            r.mean_alpha
        );
    }
    for r in &results {
        if r.name != "baseline" {
            println!(
                "{}: simulated per-request speedup vs baseline = {:.2}x",
                r.name,
                baseline_p50 / r.sim_p50_ms
            );
        }
    }

    streaming_cancel_demo(max_inflight, &workload)?;
    Ok(())
}

fn base_cfg(max_inflight: usize) -> RunConfig {
    RunConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        design_variant: 1,
        heterogeneous: true,
        max_new_tokens: 64,
        workers: 1,
        max_inflight,
        ..RunConfig::default()
    }
}

fn run_one(
    name: &'static str,
    cfg: RunConfig,
    workload: &Workload,
) -> anyhow::Result<RunResult> {
    let coord = Arc::new(Coordinator::start(cfg, Platform::imx95())?);
    let server = Server::start(Arc::clone(&coord), Tokenizer::builtin(), 0)?;
    let mut client = Client::connect(server.port)?;
    // Client hardening: a dead server surfaces as a typed error instead
    // of hanging the load generator forever.
    client.set_read_timeout(Some(Duration::from_secs(120)))?;

    let t0 = std::time::Instant::now();
    let mut sim = Summary::new();
    let mut real = Summary::new();
    let mut alphas = Summary::new();
    let mut tokens = 0u64;
    let mut streamed_demo = false;
    for req in &workload.requests {
        // Open-loop arrivals: wait until this request's arrival time.
        let due = req.arrival_s;
        let now = t0.elapsed().as_secs_f64();
        if due > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(due - now));
        }
        // Strip BOS and trailing SEP: the server re-encodes the raw text.
        let text: String = Tokenizer::builtin().decode(&req.prompt);
        let text = text.trim_end_matches('=').to_string();
        let req_id = req.id + 1;
        let reply = if !streamed_demo {
            // First request per config: exercise the v2 streaming
            // protocol and show the round-by-round frames.
            streamed_demo = true;
            let (frames, final_reply) =
                client.generate_stream_with(&text, &req.task, req_id, &GenOptions::default())?;
            println!(
                "{name}: streamed {} round frame(s) for the first request \
                 (draft windows: {:?})",
                frames.len(),
                frames
                    .iter()
                    .filter_map(|f| f.get("drafted").and_then(Json::as_usize))
                    .collect::<Vec<_>>()
            );
            final_reply
        } else {
            client.generate_with(&text, &req.task, req_id, &GenOptions::default())?
        };
        anyhow::ensure!(
            reply.get("ok") == Some(&Json::Bool(true)),
            "{name}: server error: {reply}"
        );
        sim.push(reply.req_f64("sim_ms")?);
        real.push(reply.req_f64("real_ms")?);
        tokens += reply.req_usize("tokens")? as u64;
        if let Some(a) = reply.get("alpha").and_then(Json::as_f64) {
            if a.is_finite() {
                alphas.push(a);
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let mut mj = Json::obj();
    mj.set("cmd", "metrics".into());
    if let Ok(m) = client.call(&mj) {
        println!(
            "{name}: {} scheduler rounds, mean per-round gamma {:.2}, \
             sessions in flight mean {:.2} / max {}, finish: stop={} length={}",
            m.get("rounds").and_then(Json::as_usize).unwrap_or(0),
            m.get("mean_round_gamma").and_then(Json::as_f64).unwrap_or(f64::NAN),
            m.get("mean_inflight").and_then(Json::as_f64).unwrap_or(f64::NAN),
            m.get("max_inflight").and_then(Json::as_usize).unwrap_or(0),
            m.get("finish_stop").and_then(Json::as_usize).unwrap_or(0),
            m.get("finish_length").and_then(Json::as_usize).unwrap_or(0),
        );
    }

    let mut sd = Json::obj();
    sd.set("cmd", "shutdown".into());
    let _ = client.call(&sd);
    server.stop();
    Arc::try_unwrap(coord).ok().unwrap().shutdown();

    println!(
        "{name}: {} requests in {:.1}s wall, {:.1} tok/s",
        workload.requests.len(),
        wall_s,
        tokens as f64 / wall_s
    );
    Ok(RunResult {
        name,
        wall_s,
        tokens,
        sim_p50_ms: sim.median(),
        sim_p90_ms: sim.percentile(90.0),
        real_p50_ms: real.median(),
        mean_alpha: if alphas.is_empty() { f64::NAN } else { alphas.mean() },
    })
}

/// Lifecycle demo: connection A streams a request; connection B cancels
/// it by req_id mid-stream. The session aborts at its next round
/// boundary, the slot frees, and the final frame reports the typed
/// finish reason with the tokens committed so far.
fn streaming_cancel_demo(max_inflight: usize, workload: &Workload) -> anyhow::Result<()> {
    println!("\n=== streaming-with-cancel demo ===");
    let mut cfg = base_cfg(max_inflight);
    cfg.gamma = Some(1); // small rounds: many boundaries for the abort
    let coord = Arc::new(Coordinator::start(cfg, Platform::imx95())?);
    let server = Server::start(Arc::clone(&coord), Tokenizer::builtin(), 0)?;
    let mut a = Client::connect(server.port)?;
    let mut b = Client::connect(server.port)?;
    a.set_read_timeout(Some(Duration::from_secs(60)))?;
    b.set_read_timeout(Some(Duration::from_secs(60)))?;

    let text: String = Tokenizer::builtin().decode(&workload.requests[0].prompt);
    let text = text.trim_end_matches('=').to_string();
    let req_id = 9001u64;
    let mut line = Json::obj();
    line.set("v", 2usize.into())
        .set("req_id", (req_id as usize).into())
        .set("prompt", Json::Str(text))
        .set("task", Json::Str("translate".into()))
        .set("stream", true.into());
    a.send(&line)?;
    let first = a.read_reply()?;
    let fin = if first.get("frame").and_then(Json::as_str) != Some("tokens") {
        // The request errored (or finished) in a single line — nothing
        // left to cancel or drain.
        first
    } else {
        println!(
            "A: first frame round={} text={:?}",
            first.get("round").and_then(Json::as_usize).unwrap_or(0),
            first.get("text").and_then(Json::as_str).unwrap_or(""),
        );
        let cancel_reply = b.cancel(req_id)?;
        println!("B: cancel(req_id={req_id}) -> {cancel_reply}");
        // Drain A's stream to the terminating line.
        loop {
            let l = a.read_reply()?;
            if l.get("frame").and_then(Json::as_str) != Some("tokens") {
                break l;
            }
        }
    };
    println!(
        "A: final finish={:?} tokens={} ({})",
        fin.get("finish").and_then(Json::as_str).unwrap_or("<error reply>"),
        fin.get("tokens").and_then(Json::as_usize).unwrap_or(0),
        if fin.get("finish").and_then(Json::as_str) == Some("cancelled") {
            "aborted at a round boundary, partial output returned"
        } else {
            "the decode finished before the cancel landed"
        }
    );

    let mut sd = Json::obj();
    sd.set("cmd", "shutdown".into());
    let _ = a.call(&sd);
    server.stop();
    Arc::try_unwrap(coord).ok().unwrap().shutdown();
    Ok(())
}
