//! Quantization ablation (companion to paper Fig. 5): how the quantization
//! pairing changes acceptance rate AND what that does to the end-to-end
//! decision, per the cost model.
//!
//! The pairing grid comes from the manifest itself through
//! [`DrafterRegistry::pairings`] — the same enumeration the per-class
//! drafter selection scores at serving time — so a manifest that ships
//! more quantized drafter bodies automatically widens this ablation.
//! For each (drafter, target) pairing that fits the paper-scale memory
//! budget, measures α on a slice of translate samples, then runs the DSE
//! at that measured α to show which pairings still justify speculation.
//!
//! ```bash
//! cargo run --release --example quant_ablation -- [samples_per_pair]
//! ```

use specedge::config::KernelPath;
use specedge::dse::{self, PairConfig};
use specedge::experiments::alpha::measure_alpha;
use specedge::hetero::{LatencyModel, Platform};
use specedge::runtime::Engine;
use specedge::scenario::DrafterRegistry;
use specedge::tokenizer::Tokenizer;
use specedge::util::stats::Summary;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(6);
    let engine = Engine::load(Path::new("artifacts"))?;
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec)?;
    let lat = LatencyModel::new(Platform::imx95());

    let registry = DrafterRegistry::from_manifest(&engine.manifest)?;
    let pairings = registry.pairings(&engine.manifest);

    println!(
        "quantization ablation — {} translate samples per pairing (qmax = {})\n",
        n, engine.manifest.qmax
    );
    println!("{:<26} {:>8} {:>8} {:>8} {:>10} {:>8} {:>9}",
             "pairing", "fits?", "a_med", "a_p90", "decision", "gamma", "S_pred");

    let samples: Vec<_> = engine
        .manifest
        .eval_samples
        .iter()
        .filter(|s| s.task == "translate")
        .take(n)
        .cloned()
        .collect();

    for (d, t) in pairings {
        let label = format!("{} + {}", d.name(), t.name());
        let fits = lat.platform.memory.pair_fits(t.scheme, d.scheme);
        if !fits {
            // Reproduces paper §IV-A footnote 2: these pairings cannot even
            // initialize on the device at Llama-3.2 scale.
            println!("{label:<26} {:>8} {:>8} {:>8} {:>10} {:>8} {:>9}",
                     "NO(mem)", "-", "-", "-", "-", "-");
            continue;
        }
        let mut a = Summary::new();
        for s in &samples {
            let v = measure_alpha(&engine, &tokenizer, d, t, KernelPath::Pallas, s, 40)?;
            if v.is_finite() {
                a.push(v);
            }
        }
        let med = a.median();
        let pair = PairConfig {
            target: engine.manifest.model_for(t)?.clone(),
            target_scheme: t.scheme,
            drafter: engine.manifest.model_for(d)?.clone(),
            drafter_scheme: d.scheme,
        };
        let decision = dse::explore_variant(&lat, &pair, 1, med, 63);
        let b = &decision.best;
        println!(
            "{label:<26} {:>8} {:>8.2} {:>8.2} {:>10} {:>8} {:>9.2}",
            "yes",
            med,
            a.percentile(90.0),
            if b.gamma > 0 { "speculate" } else { "baseline" },
            b.gamma,
            b.speedup
        );
    }
    println!(
        "\n(the fp/fp and drafter-only-quant rows exercise the memory gate at \
         Llama-3.2 scale — see hetero::platform::MemoryModel)"
    );
    Ok(())
}
