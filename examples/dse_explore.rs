//! Design-space exploration walkthrough (paper §III-B, Tables II/III).
//!
//! Prints the full v·N^m candidate space at several (α, S_L) operating
//! points — every mapping with its cost coefficient, feasibility verdict,
//! chosen γ and predicted speedup — then the per-variant decisions.
//!
//! ```bash
//! cargo run --release --example dse_explore -- [alpha] [seq_len]
//! ```

use specedge::dse::{self, PairConfig};
use specedge::hetero::{LatencyModel, Platform};
use specedge::models::{Scheme, VariantKey};
use specedge::runtime::Manifest;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let alpha: f64 = args.first().and_then(|a| a.parse().ok()).unwrap_or(0.90);
    let seq: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(63);

    let manifest = Manifest::load(Path::new("artifacts"))?;
    let lat = LatencyModel::new(Platform::imx95());
    let pair = PairConfig {
        target: manifest.model_for(VariantKey::parse("target_w8a8")?)?.clone(),
        target_scheme: Scheme::W8a8,
        drafter: manifest.model_for(VariantKey::parse("drafter_fp")?)?.clone(),
        drafter_scheme: Scheme::Fp,
    };

    let v = lat.platform.design_variants();
    println!(
        "design space: v = {} variants x N^m = 2^2 assignments = {} mappings",
        v,
        dse::design_space_size(v, 2, 2)
    );
    println!("operating point: alpha = {alpha}, S_L = {seq}\n");

    println!("{:<8} {:<38} {:>8} {:>6} {:>9} {}",
             "variant", "mapping", "c", "gamma", "speedup", "verdict");
    let decisions = dse::explore_all(&lat, &pair, alpha, seq);
    for d in &decisions {
        for cand in &d.all {
            let verdict = match cand.infeasible {
                Some(i) => format!("{i:?}"),
                None if cand.gamma > 0 => "speculate".to_string(),
                None => "no gain".to_string(),
            };
            println!(
                "{:<8} {:<38} {:>8} {:>6} {:>9.3} {}",
                cand.variant,
                cand.mapping.label(),
                if cand.c.is_nan() { "-".into() } else { format!("{:.3}", cand.c) },
                cand.gamma,
                cand.speedup,
                verdict
            );
        }
    }

    println!("\nper-variant decisions (Table II/III layout):");
    for d in &decisions {
        let b = &d.best;
        println!(
            "variant {}: {:<24} heterogeneous={:<5} S={:.2}",
            b.variant,
            if b.gamma > 0 { format!("speculate (gamma={})", b.gamma) }
            else { "no speculation".into() },
            if b.gamma > 0 { b.mapping.is_heterogeneous().to_string() }
            else { "n/a".into() },
            b.speedup
        );
    }

    // Bonus: how the decision shifts across the α range (the Fig. 7 story).
    println!("\nvariant-1 decision vs alpha:");
    for i in 0..=10 {
        let a = i as f64 / 10.0;
        let d = dse::explore_variant(&lat, &pair, 1, a, seq);
        println!(
            "  alpha {:.1}: gamma={} S={:.2} [{}]",
            a, d.best.gamma, d.best.speedup, d.best.mapping.label()
        );
    }
    Ok(())
}
