//! Quickstart: load the AOT artifacts and serve one prompt through the
//! request-lifecycle API.
//!
//! ```bash
//! make artifacts && cargo build --release
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the whole three-layer story in ~60 lines: the Pallas/JAX-built
//! HLO artifacts load into a Rust PJRT engine behind a serving
//! `Coordinator`, one `submit` returns a `RequestHandle` that streams
//! speculation rounds as they commit, typed `GenOptions` flip the same
//! request to baseline decoding for an A/B comparison, and both the
//! simulated-i.MX95 and real wall-clock latencies come back with a typed
//! finish reason.

use specedge::api::{GenOptions, GenerationRequest};
use specedge::config::RunConfig;
use specedge::coordinator::Coordinator;
use specedge::hetero::Platform;
use specedge::runtime::Manifest;
use specedge::tokenizer::{Tokenizer, SEP_ID};
use std::path::{Path, PathBuf};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Path::new("artifacts"))?;
    let tokenizer = Tokenizer::from_manifest(&manifest.tokenizer_spec)?;

    // Pick a real translation sample from the benchmark set.
    let sample = manifest
        .eval_samples
        .iter()
        .find(|s| s.task == "translate")
        .expect("translate sample in manifest");
    println!("prompt:     {}", sample.prompt);
    println!("reference:  {}", sample.completion);

    let mut prompt = tokenizer.encode(&sample.prompt, true)?;
    prompt.push(SEP_ID);

    // The paper's deployed configuration: γ=5 speculation on the
    // variant-1 heterogeneous mapping (fp drafter on the GPU, quantized
    // target on one CPU core).
    let cfg = RunConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        gamma: Some(5),
        ..RunConfig::default()
    };
    let coord = Coordinator::start(cfg, Platform::imx95())?;

    // Speculative request: stream each round's committed tokens live.
    let handle = coord.submit(GenerationRequest::new(1, "translate", prompt.clone()));
    print!("generated: ");
    for frame in handle.frames() {
        print!("{}", tokenizer.decode(&frame.tokens));
    }
    println!();
    let spec = handle.wait()?;

    // Same prompt, forced to plain autoregressive decoding via the
    // per-request speculation hint — the A/B baseline.
    let baseline_req = GenerationRequest::new(2, "translate", prompt)
        .with_options(GenOptions { no_spec: true, ..GenOptions::default() });
    let base = coord.submit(baseline_req).wait()?;
    coord.shutdown();

    println!();
    println!(
        "baseline:    {:6.1} ms simulated ({} tokens, finish = {})",
        base.sim_s * 1e3,
        base.tokens.len(),
        base.finish.as_str()
    );
    println!(
        "speculative: {:6.1} ms simulated ({} rounds, alpha = {:.2}, finish = {})",
        spec.sim_s * 1e3,
        spec.rounds,
        spec.alpha,
        spec.finish.as_str()
    );
    println!("speedup:     {:.2}x", base.sim_s / spec.sim_s);
    Ok(())
}
