//! Quickstart: load the AOT artifacts and speculatively decode one prompt.
//!
//! ```bash
//! make artifacts && cargo build --release
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the whole three-layer story in ~40 lines: the Pallas/JAX-built HLO
//! artifacts load into a Rust PJRT engine, a drafter+target pair runs the
//! paper's speculative-sampling loop on the paper's deployed mapping
//! (variant 1: fp drafter on the GPU, quantized target on one CPU core),
//! and both the simulated-i.MX95 and real wall-clock latencies come back.

use specedge::config::{ExecMode, KernelPath};
use specedge::hetero::{LatencyModel, Mapping, Platform};
use specedge::models::VariantKey;
use specedge::runtime::Engine;
use specedge::spec::{AcceptRule, Decoder, DecoderSetup};
use specedge::tokenizer::{Tokenizer, SEP_ID};

fn main() -> anyhow::Result<()> {
    let engine = Engine::load(std::path::Path::new("artifacts"))?;
    let tokenizer = Tokenizer::from_manifest(&engine.manifest.tokenizer_spec)?;

    // Pick a real translation sample from the benchmark set.
    let sample = engine
        .manifest
        .eval_samples
        .iter()
        .find(|s| s.task == "translate")
        .expect("translate sample in manifest");
    println!("prompt:     {}", sample.prompt);
    println!("reference:  {}", sample.completion);

    let mut prompt = tokenizer.encode(&sample.prompt, true)?;
    prompt.push(SEP_ID);

    let setup = DecoderSetup {
        drafter: VariantKey::parse("drafter_fp")?,
        target: VariantKey::parse("target_w8a8")?,
        kernel: KernelPath::Pallas,
        mapping: Mapping::heterogeneous(1), // paper's best variant
        gamma: 5,
        rule: AcceptRule::Greedy,
        exec: ExecMode::Modular,
        max_new: 64,
    };
    let decoder = Decoder::new(&engine, LatencyModel::new(Platform::imx95()), setup);

    let base = decoder.baseline(&prompt)?;
    let spec = decoder.speculative(&prompt)?;

    println!("generated:  {}", tokenizer.decode(&spec.tokens));
    println!();
    println!(
        "baseline:    {:6.1} ms simulated ({} target calls)",
        base.sim_s * 1e3, base.target_calls
    );
    println!(
        "speculative: {:6.1} ms simulated ({} rounds, alpha = {:.2})",
        spec.sim_s * 1e3, spec.n_rounds, spec.alpha()
    );
    println!("speedup:     {:.2}x", base.sim_s / spec.sim_s);
    Ok(())
}
